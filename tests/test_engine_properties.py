"""Hypothesis property tests on the distributed-engine invariants that
hold independent of device count (host-side: permutation algebra, spec
resolution, padding rules) plus HLO-analyzer parser regressions."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st

from jax.sharding import PartitionSpec as P

from repro.core.cannon import _skew_perm, _shift_perm
from repro.core.cannon25d import _skew25d_perm
from repro.kernels.smm.ops import mxu_pad_shape
from repro.launch import hlo_analysis as H


# ---------------------------------------------------------------------------
# permutation algebra (a wrong perm deadlocks or corrupts a real run —
# these invariants are the cheap static guarantee)
# ---------------------------------------------------------------------------


@given(st.integers(2, 8), st.sampled_from(["a", "b"]))
@settings(max_examples=30, deadline=None)
def test_skew_perm_is_bijection(pg, which):
    pairs = _skew_perm(pg, which)
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    assert sorted(srcs) == list(range(pg * pg))
    assert sorted(dsts) == list(range(pg * pg))


@given(st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_skew_perm_row_preserving(pg):
    # A's skew moves data only within its grid row
    for s, d in _skew_perm(pg, "a"):
        assert s // pg == d // pg
    # B's skew moves data only within its grid column
    for s, d in _skew_perm(pg, "b"):
        assert s % pg == d % pg


@given(st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_shift_perm_order(pg):
    """Applying the circular shift pg times is the identity."""
    perm = dict(_shift_perm(pg))
    for start in range(pg):
        x = start
        for _ in range(pg):
            x = perm[x]
        assert x == start


@given(st.sampled_from([(2, 1), (2, 2), (4, 2), (4, 4), (6, 2), (6, 3),
                        (8, 2), (8, 4)]),
       st.sampled_from(["a", "b"]))
@settings(max_examples=30, deadline=None)
def test_skew25d_perm_is_pod_local_bijection(pgc, which):
    pg, c = pgc
    spr = pg // c
    pairs = _skew25d_perm(pg, c, spr, which)
    n = c * pg * pg
    assert sorted(s for s, _ in pairs) == list(range(n))
    assert sorted(d for _, d in pairs) == list(range(n))
    # replicas never exchange data during the skew
    for s, d in pairs:
        assert s // (pg * pg) == d // (pg * pg)


def test_skew25d_phase_offsets():
    """Replica p must start at k-phase (i + j + p*spr) mod P."""
    pg, c = 4, 2
    spr = pg // c
    pairs = dict()
    for s, d in _skew25d_perm(pg, c, spr, "a"):
        pairs[d] = s
    for p in range(c):
        for i in range(pg):
            for j in range(pg):
                dst = (p * pg + i) * pg + j
                src = pairs[dst]
                src_j = src % pg
                assert src_j == (i + j + p * spr) % pg


# ---------------------------------------------------------------------------
# spec resolution / padding rules
# ---------------------------------------------------------------------------


@given(st.integers(1, 512), st.integers(1, 512), st.integers(1, 512))
@settings(max_examples=50, deadline=None)
def test_mxu_pad_shape_properties(bm, bk, bn):
    pm, pk, pn = mxu_pad_shape(bm, bk, bn, align=True)
    assert pm % 8 == 0 and pk % 128 == 0 and pn % 128 == 0
    assert pm >= bm and pk >= bk and pn >= bn
    assert pm - bm < 8 and pk - bk < 128 and pn - bn < 128  # minimality
    assert mxu_pad_shape(bm, bk, bn, align=False) == (bm, bk, bn)


@given(st.integers(1, 64), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_head_pad_group_mapping_invariant(hkv, n_rep):
    """head_pad_factor=c preserves the q-head -> kv-group map exactly."""
    h = hkv * n_rep
    for c in (2, 3, 4):
        h_eff, hkv_eff = h * c, hkv * c
        assert h_eff % hkv_eff == 0
        n_rep_eff = h_eff // hkv_eff
        assert n_rep_eff == n_rep           # grouping unchanged
        for j in range(h):                  # every REAL head, same group
            assert j // n_rep_eff == j // n_rep


def test_resolve_spec_rules():
    import types
    from repro.models.common import resolve_spec
    # resolve_spec only consults mesh.shape — no devices needed
    mesh = types.SimpleNamespace(shape={"data": 2, "model": 4})
    # non-divisible dim loses its axis
    assert resolve_spec(P(None, "model", None), (8, 3, 4), mesh) \
        == P(None, None, None)
    # divisible dim keeps it
    assert resolve_spec(P(None, "model"), (8, 8), mesh) == P(None, "model")
    # absent axes are dropped ('pod' not in this mesh)
    assert resolve_spec(P(("pod", "data"), None), (8, 8), mesh) \
        == P("data", None)
    # tuple axes: total extent must divide
    assert resolve_spec(P(("data", "model"), None), (8, 8), mesh) \
        == P(("data", "model"), None)
    assert resolve_spec(P(("data", "model"), None), (4, 8), mesh) \
        == P(None, None)


# ---------------------------------------------------------------------------
# HLO analyzer parser regressions
# ---------------------------------------------------------------------------

MINI_HLO = """HloModule test, num_partitions=4

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[2,2]<=[4], to_apply=%add
  %t = (s32[], f32[8,16]{1,0}) tuple(%i, %ar)
  ROOT %r = (s32[], f32[8,16]{1,0}) copy(%t)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%x)
  %wh = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %o = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_analyzer_trip_count_and_flops():
    costs = H.analyze_hlo(MINI_HLO)
    # dot: 2*8*16*16 = 4096 flops, x5 trips
    assert costs.flops == 5 * 2 * 8 * 16 * 16
    # all-reduce: 8*16*4B payload, group size 2 -> 2*(1/2)*512 = 512 B, x5
    assert costs.collective_bytes["all-reduce"] == 5 * 512.0
    assert costs.unknown_trip_loops == 0


def test_analyzer_shape_parsing():
    assert H._nbytes("f32[8,16]{1,0}") == 512
    assert H._nbytes("(s32[], bf16[4,4]{1,0})") == 4 + 32
    assert H._nbytes("pred[]") == 1
    name, type_str, opcode, rest = H._parse_op_line(
        "  %wh = (s32[], f32[8,16]{1,0}, /*index=2*/f32[2]{0}) "
        "while(%init), condition=%c, body=%b")
    assert opcode == "while"
    assert H._attr(rest, "body") == "b"
