"""decode_attention Pallas kernel vs oracle: shape/dtype/cur_len sweeps
(interpret mode) + agreement with the model layer's decode math."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.models.attention import decode_attention as model_decode


CASES = [
    # (B, Hkv, R, Dh, S, cur_len, block_k)
    (2, 2, 4, 64, 256, 200, 128),
    (1, 1, 8, 128, 512, 512, 256),   # MQA, full cache
    (2, 4, 1, 64, 128, 7, 64),       # MHA (R=1), short valid prefix
    (1, 2, 6, 32, 384, 100, 128),    # GQA 6:1, unaligned cur_len
]


@pytest.mark.parametrize("b,hkv,r,dh,s,cur,bk", CASES)
def test_kernel_vs_ref(b, hkv, r, dh, s, cur, bk, rng):
    q = rng.randn(b, hkv, r, dh).astype(np.float32)
    k = rng.randn(b, s, hkv, dh).astype(np.float32)
    v = rng.randn(b, s, hkv, dh).astype(np.float32)
    out = decode_attention(
        jnp.asarray(q).reshape(b, 1, hkv * r, dh),
        jnp.asarray(k), jnp.asarray(v), jnp.asarray(cur),
        block_k=bk)
    ref = decode_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(cur))
    np.testing.assert_allclose(
        np.asarray(out).reshape(b, hkv, r, dh), np.asarray(ref),
        rtol=2e-4, atol=2e-4)


def test_kernel_vs_model_layer(rng):
    """Kernel agrees with the pure-jnp decode path used by the models."""
    b, hkv, r, dh, s, cur = 2, 2, 3, 64, 256, 123
    h = hkv * r
    q = jnp.asarray(rng.randn(b, 1, h, dh).astype(np.float32))
    k = jnp.asarray(rng.randn(b, s, hkv, dh).astype(np.float32))
    v = jnp.asarray(rng.randn(b, s, hkv, dh).astype(np.float32))
    out_kernel = decode_attention(q, k, v, jnp.asarray(cur))
    out_model = model_decode(q, k, v, jnp.asarray(cur), scale=dh ** -0.5)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=2e-4, atol=2e-4)


def test_kernel_bf16_cache(rng):
    b, hkv, r, dh, s, cur = 1, 2, 4, 64, 256, 250
    q = rng.randn(b, 1, hkv * r, dh).astype(np.float32)
    k = rng.randn(b, s, hkv, dh).astype(np.float32)
    v = rng.randn(b, s, hkv, dh).astype(np.float32)
    out = decode_attention(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
        jnp.asarray(v, jnp.bfloat16), jnp.asarray(cur))
    ref = decode_attention_ref(
        jnp.asarray(q).reshape(b, hkv, r, dh), jnp.asarray(k),
        jnp.asarray(v), jnp.asarray(cur))
    np.testing.assert_allclose(
        np.asarray(out, np.float32).reshape(b, hkv, r, dh),
        np.asarray(ref), rtol=5e-2, atol=5e-2)


def test_cur_len_zero_and_one(rng):
    """Degenerate valid lengths must not produce NaNs."""
    b, hkv, r, dh, s = 1, 1, 2, 32, 64
    q = jnp.asarray(rng.randn(b, 1, hkv * r, dh).astype(np.float32))
    k = jnp.asarray(rng.randn(b, s, hkv, dh).astype(np.float32))
    v = jnp.asarray(rng.randn(b, s, hkv, dh).astype(np.float32))
    out1 = decode_attention(q, k, v, jnp.asarray(1))
    assert np.isfinite(np.asarray(out1)).all()
    # cur_len=1: attention collapses onto position 0
    np.testing.assert_allclose(
        np.asarray(out1)[0, 0, 0], np.asarray(v)[0, 0, 0], rtol=1e-4)
