"""Telemetry battery: metrics registry, span nesting, exporters, the
disabled-path zero-overhead contract, and predicted-vs-actual planner
accounting (repro.obs).

The hard contract under test (ISSUE 8 acceptance): with telemetry
DISABLED (the default) the multiply paths are bitwise identical to an
enabled-then-disabled process and add ZERO registry entries; with it
ENABLED one ``dbcsr.multiply`` leaves a well-formed span tree whose
synthetic schedule-step durations sum consistently with the measured
dispatch wall time, exports valid Chrome-trace JSON, and records a
predicted-vs-measured plan outcome for the scoreboard.
"""
import json
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import obs  # noqa: E402
from repro.compat import make_mesh  # noqa: E402
from repro.core import dbcsr  # noqa: E402
from repro.core.blocking import GridSpec  # noqa: E402
from repro.core.multiply import distributed_matmul  # noqa: E402

EXEC_KW = dict(algorithm="cannon", densify=False, local_kernel="ref",
               pipeline_depth=1)


@pytest.fixture()
def rng():
    """Module-local stream: this file must NOT consume the session-scoped
    conftest rng — later test files' data depends on its position."""
    return np.random.RandomState(0)


def _reset_obs():
    obs.enable()   # reset=True installs a fresh, empty tracer ...
    obs.disable()  # ... and the default state is OFF
    obs.clear_metrics()
    obs.clear_plan_outcomes()


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with telemetry off and empty stores."""
    _reset_obs()
    yield
    _reset_obs()


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _operand(rng, m, n, *, block=32, mesh=None):
    return dbcsr.create(rng.randn(m, n).astype(np.float32), mesh=mesh,
                        block_size=block)


def _spans_by_name(spans, name):
    return [s for s in spans if s.name == name]


def _children(spans, parent):
    return [s for s in spans if s.parent_id == parent.span_id]


# ---------------------------------------------------------------------------
# metrics registry units
# ---------------------------------------------------------------------------


def test_counter_inc_and_negative_rejected():
    c = obs.counter("t.count")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create returns the same instance
    assert obs.counter("t.count") is c


def test_labels_isolate_series():
    a = obs.counter("t.lbl", algo="cannon")
    b = obs.counter("t.lbl", algo="summa")
    a.inc(3)
    assert b.value == 0 and a.value == 3
    # label order must not matter
    assert obs.counter("t.two", x="1", y="2") is obs.counter(
        "t.two", y="2", x="1")


def test_gauge_keeps_sample_history():
    g = obs.gauge("t.occ")
    for v in (0.2, 0.9, 0.4):
        g.set(v)
    assert g.value == 0.4
    assert g.samples == [0.2, 0.9, 0.4]


def test_histogram_percentiles_match_numpy():
    h = obs.histogram("t.lat")
    rng = np.random.RandomState(7)
    vals = rng.rand(101).tolist()
    for v in vals:
        h.observe(v)
    for p in (50, 90, 99):
        assert h.percentile(p) == pytest.approx(
            float(np.percentile(np.asarray(vals), p)), abs=1e-12)
    assert h.count == 101
    # empty histogram is defined (the service's zero-request case)
    assert obs.histogram("t.empty").percentile(99) == 0.0


def test_registry_snapshot_and_clear():
    obs.counter("t.a").inc()
    obs.gauge("t.b").set(1.0)
    obs.histogram("t.c").observe(2.0)
    assert len(obs.registry()) == 3
    snap = obs.metrics_snapshot()
    assert len(snap) == 3
    obs.clear_metrics()
    assert len(obs.registry()) == 0


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------


def test_span_nesting_and_last_trace():
    tracer = obs.enable()
    with obs.span("outer", cat="multiply"):
        with obs.span("inner", cat="plan") as sp:
            sp.set(algorithm="cannon")
    outer = _spans_by_name(tracer.spans, "outer")[0]
    inner = _spans_by_name(tracer.spans, "inner")[0]
    assert inner.parent_id == outer.span_id
    assert inner.trace_id == outer.trace_id == outer.span_id
    assert inner.attrs["algorithm"] == "cannon"
    assert {s.name for s in obs.last_trace()} == {"outer", "inner"}


def test_span_disabled_is_shared_noop():
    assert obs.span("x") is obs.NOOP_SPAN
    assert obs.maybe_span(False, "x") is obs.NOOP_SPAN
    with obs.span("x") as sp:      # must be safely enterable
        sp.set(ignored=1)
    assert obs.last_trace() == []


def test_span_exception_tagged_and_stack_recovers():
    tracer = obs.enable()
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    rec = _spans_by_name(tracer.spans, "boom")[0]
    assert rec.attrs["error"] == "RuntimeError"
    assert tracer.current() is None  # stack popped despite the raise


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _toy_trace():
    tracer = obs.enable()
    with obs.span("root", cat="multiply"):
        with obs.span("child", cat="plan"):
            pass
    return obs.last_trace()


def test_chrome_trace_valid_and_written(tmp_path):
    spans = _toy_trace()
    chrome = obs.to_chrome_trace(spans)
    assert obs.validate_chrome_trace(chrome) == []
    path = str(tmp_path / "trace.json")
    obs.write_chrome_trace(path, spans)
    with open(path) as f:
        assert obs.validate_chrome_trace(json.load(f)) == []


def test_chrome_trace_validator_catches_tampering():
    chrome = obs.to_chrome_trace(_toy_trace())
    xs = [e for e in chrome["traceEvents"] if e["ph"] == "X"]
    xs[0]["dur"] = -5.0                      # negative duration
    xs[1]["args"]["parent_id"] = 10 ** 9     # orphan parent
    errors = obs.validate_chrome_trace(chrome)
    assert errors
    assert obs.validate_chrome_trace({"traceEvents": []})
    assert obs.validate_chrome_trace([1, 2, 3])


def test_jsonl_event_log_round_trip(tmp_path):
    log_dir = str(tmp_path / "obs")
    obs.enable(log_dir=log_dir)
    with obs.span("root", cat="multiply"):
        pass
    obs.record_plan_outcome(algorithm="cannon", predicted_s=1.0,
                            measured_s=2.0)
    events = obs.read_jsonl(os.path.join(log_dir, obs.EVENTS_LOG))
    outcomes = obs.read_jsonl(os.path.join(log_dir, obs.PLAN_OUTCOMES_LOG))
    assert [e["name"] for e in events] == ["root"]
    assert outcomes == [{"algorithm": "cannon", "predicted_s": 1.0,
                         "measured_s": 2.0}]
    # round-trip through SpanRecord for the report CLI
    rec = obs.SpanRecord.from_dict(events[0])
    assert rec.name == "root" and rec.dur >= 0
    assert obs.read_jsonl(str(tmp_path / "missing.jsonl")) == []


def test_report_cli(tmp_path, capsys):
    from repro.obs import report

    log_dir = str(tmp_path / "obs")
    assert report.main(["--dir", log_dir]) == 1  # no logs yet
    capsys.readouterr()
    obs.enable(log_dir=log_dir)
    with obs.span("multiply", cat="multiply"):
        with obs.span("plan", cat="plan"):
            pass
    obs.record_plan_outcome(algorithm="cannon", predicted_s=1.0,
                            measured_s=2.0)
    obs.disable()
    assert report.main(["--dir", log_dir, "--timeline"]) == 0
    out = capsys.readouterr().out
    assert "plan" in out and "cannon" in out and "scoreboard" in out


# ---------------------------------------------------------------------------
# the zero-overhead-off contract
# ---------------------------------------------------------------------------


def test_disabled_is_bitwise_identical_and_adds_no_metrics(rng):
    mesh = _mesh11()
    a = _operand(rng, 128, 128, mesh=mesh)
    b = _operand(rng, 128, 128, mesh=mesh)
    kw = dict(mesh=mesh, **EXEC_KW)

    obs.clear_metrics()
    c_off = dbcsr.multiply(a, b, **kw)
    jax.block_until_ready(c_off.data)
    assert len(obs.registry()) == 0, \
        "disabled multiply must add zero registry entries"
    assert obs.last_trace() == []

    obs.enable()
    c_on = dbcsr.multiply(a, b, **kw)
    jax.block_until_ready(c_on.data)
    obs.disable()
    c_off2 = dbcsr.multiply(a, b, **kw)

    assert (np.asarray(c_on.data) == np.asarray(c_off.data)).all()
    assert (np.asarray(c_off2.data) == np.asarray(c_off.data)).all()


def test_enabled_under_jit_records_nothing(rng):
    # operands are jax tracers under jit: the per-call _tele flag must
    # veto spans even though the global switch is on
    mesh = _mesh11()
    grid = GridSpec("data", "model")
    A = rng.randn(64, 64).astype(np.float32)
    B = rng.randn(64, 64).astype(np.float32)
    tracer = obs.enable()

    fn = jax.jit(lambda x, y: distributed_matmul(
        x, y, mesh=mesh, grid=grid, block_m=32, block_k=32, block_n=32,
        **EXEC_KW))
    C = jax.block_until_ready(fn(A, B))
    np.testing.assert_allclose(np.asarray(C), A @ B, rtol=2e-4, atol=2e-4)
    assert tracer.spans == []
    assert obs.plan_outcomes() == []


# ---------------------------------------------------------------------------
# traced multiply: span tree, durations, plan outcome
# ---------------------------------------------------------------------------


def test_traced_multiply_span_tree_and_outcome(rng):
    mesh = _mesh11()
    a = _operand(rng, 128, 128, mesh=mesh)
    b = _operand(rng, 128, 128, mesh=mesh)
    obs.enable()
    c, plan = dbcsr.multiply(a, b, mesh=mesh, return_plan=True, **EXEC_KW)
    jax.block_until_ready(c.data)
    obs.disable()

    spans = obs.last_trace()
    (root,) = [s for s in spans if s.parent_id is None]
    assert root.name == "multiply" and root.cat == "multiply"
    kids = {s.name: s for s in _children(spans, root)}
    assert set(kids) == {"plan", "dispatch"}
    assert kids["plan"].attrs["algorithm"] == "cannon"
    disp = kids["dispatch"]
    assert disp.attrs["comm_bytes"] >= 0

    # synthetic schedule-step children fill the measured dispatch
    # interval: sum(children) ~= dispatch dur, root covers dispatch
    steps = _children(spans, disp)
    assert steps and all(s.cat in ("comm", "schedule-step")
                         for s in steps)
    ssum = sum(s.dur for s in steps)
    assert ssum == pytest.approx(disp.dur, rel=0.1)
    assert root.dur >= disp.dur > 0
    step_spans = [s for s in steps if s.cat == "schedule-step"]
    assert all("flops" in s.attrs and "comm_bytes" in s.attrs
               for s in step_spans)

    # every traced non-trivial multiply records predicted-vs-measured
    (out,) = obs.plan_outcomes()
    assert out["algorithm"] == "cannon"
    assert out["predicted_s"] == pytest.approx(float(plan.predicted_s))
    assert 0 < out["measured_s"] <= root.dur

    # and the whole trace exports as valid Chrome-trace JSON
    assert obs.validate_chrome_trace(obs.to_chrome_trace(spans)) == []


def test_traced_fused_batched_span_tree(rng):
    mesh = _mesh11()
    pairs = [(_operand(rng, 64, 64, mesh=mesh),
              _operand(rng, 64, 64, mesh=mesh)) for _ in range(3)]
    obs.enable()
    out = dbcsr.multiply_batched(pairs, mesh=mesh, fused=True,
                                 **EXEC_KW)
    jax.block_until_ready(out[0].data)
    obs.disable()

    spans = obs.last_trace()
    (root,) = [s for s in spans if s.parent_id is None]
    assert root.name == "multiply_batched"
    assert root.attrs["n_groups"] == 3
    kids = {s.name: s for s in _children(spans, root)}
    assert set(kids) == {"plan", "dispatch"}
    assert _children(spans, kids["dispatch"]), \
        "fused dispatch must carry schedule-step children"
    # ONE fused dispatch — no nested per-request "multiply" roots
    assert _spans_by_name(spans, "multiply") == []
    # fuse-or-loop decision counters (gated, enabled here)
    assert obs.counter("batched.requests_fused").value == 3
    assert obs.counter("batched.requests_looped").value == 0
    (bout,) = obs.plan_outcomes()
    assert bout["kind"] == "multiply_batched" and bout["fuse"] is True


def test_traced_abft_repair_nests_second_dispatch(rng):
    from repro.robustness import chaos
    from repro.sparsity.norms import compute_block_norms

    mesh = _mesh11()
    a = _operand(rng, 128, 128, mesh=mesh)
    b = _operand(rng, 128, 128, mesh=mesh)
    kw = dict(mesh=mesh, verify="checksum", **EXEC_KW)
    clean = dbcsr.multiply(a, b, mesh=mesh, **EXEC_KW)

    norms = compute_block_norms(clean.data, 32, 32)
    i0, j0 = np.unravel_index(int(np.argmax(norms)), norms.shape)
    hook = chaos.FaultInjector(seed=7).one_shot_result_hook(
        int(i0), int(j0), block_m=32, block_n=32, mode="bitflip")

    obs.enable()
    with chaos.result_corruption(hook):
        cr = dbcsr.multiply(a, b, **kw)
    obs.disable()
    assert (np.asarray(cr.data) == np.asarray(clean.data)).all()

    spans = obs.last_trace()
    (root,) = [s for s in spans if s.parent_id is None]
    (verify,) = _spans_by_name(spans, "verify")
    assert verify.parent_id == root.span_id
    assert verify.attrs == {**verify.attrs, "detected": True,
                            "repaired": True, "n_flagged_blocks": 1}
    (repair,) = _spans_by_name(spans, "repair")
    assert repair.parent_id == verify.span_id
    # the repair re-execution shows up as a SECOND dispatch span,
    # nested under repair (the first is the corrupted original)
    dispatches = _spans_by_name(spans, "dispatch")
    assert len(dispatches) == 2
    assert sorted(d.parent_id for d in dispatches) == sorted(
        [root.span_id, repair.span_id])
    # ABFT registry counters (gated, enabled here)
    assert obs.counter("abft.detections").value == 1
    assert obs.counter("abft.repairs").value == 1
    # measured_s is the FIRST (pre-repair) dispatch, not the re-run
    (out,) = obs.plan_outcomes()
    first = min(dispatches, key=lambda s: s.t0)
    assert out["measured_s"] == pytest.approx(first.dur, rel=0.25)


# ---------------------------------------------------------------------------
# scoreboard + drift
# ---------------------------------------------------------------------------


def _mk_records():
    return [
        {"algorithm": "cannon", "predicted_s": 1.0, "measured_s": 1.1},
        {"algorithm": "cannon", "predicted_s": 0.9, "measured_s": 1.0},
        {"algorithm": "summa", "predicted_s": 5.0, "measured_s": 1.0},
        {"algorithm": "broken", "predicted_s": 1.0, "measured_s": 0.0},
    ]


def test_planner_scoreboard_fields():
    sb = obs.planner_scoreboard(_mk_records())
    assert set(sb) == {"cannon", "summa"}  # zero-measurement row skipped
    assert sb["cannon"]["n"] == 2
    # rel errs: (1.0-1.1)/1.1 and (0.9-1.0)/1.0 -> median is their mean
    assert sb["cannon"]["rel_err_median"] == pytest.approx(
        (-0.1 / 1.1 - 0.1) / 2.0, abs=1e-12)
    assert sb["summa"]["rel_err_median"] == pytest.approx(4.0)
    assert "cannon" in obs.render_scoreboard(sb)


def test_check_drift_flags_and_min_samples():
    res = obs.check_drift(_mk_records(), threshold=1.0)
    assert not res["ok"] and list(res["flagged"]) == ["summa"]
    ok = obs.check_drift(_mk_records(), threshold=10.0)
    assert ok["ok"] and ok["flagged"] == {}
    # below min_samples: reported but never flagged
    res2 = obs.check_drift(_mk_records(), threshold=1.0, min_samples=2)
    assert res2["ok"] and "summa" in res2["scoreboard"]


def test_calibrate_drift_report_reads_log(tmp_path):
    from repro.planner import calibrate

    path = str(tmp_path / "plan_outcomes.jsonl")
    with open(path, "w") as f:
        for r in _mk_records():
            f.write(json.dumps(r) + "\n")
    rep = calibrate.drift_report(path, threshold=1.0)
    assert not rep["ok"] and "summa" in rep["flagged"]
    assert rep["n_records"] == 4 and rep["path"] == path
    # a missing log is not drift (advisory default)
    empty = calibrate.drift_report(str(tmp_path / "nope.jsonl"))
    assert empty["ok"] and empty["n_records"] == 0


# ---------------------------------------------------------------------------
# legacy stats() dicts as registry views
# ---------------------------------------------------------------------------


def test_plan_cache_stats_is_registry_view():
    from repro.planner.plan import plan_cache_clear, plan_cache_stats, \
        plan_multiply

    plan_cache_clear()
    plan_multiply(256, 256, 256, mesh_shape=(1, 1))
    plan_multiply(256, 256, 256, mesh_shape=(1, 1))
    st = plan_cache_stats()
    assert set(st) == {"hits", "misses", "currsize", "maxsize",
                       "evictions"}
    assert st["hits"] >= 1 and st["misses"] >= 1
    # the dict is a view over registry gauges, not a second counter
    for key, val in st.items():
        assert obs.gauge(f"planner.plan_cache.{key}").value == val


def test_service_stats_is_registry_view(rng):
    from repro.serve.multiply_service import MultiplyService

    mesh = _mesh11()
    svc = MultiplyService(mesh, slo_s=0.0, max_batch=8, **EXEC_KW)
    other = MultiplyService(mesh, slo_s=0.0, max_batch=8, **EXEC_KW)
    assert svc.service_id != other.service_id
    t = [svc.submit(_operand(rng, 64, 64, mesh=mesh),
                    _operand(rng, 64, 64, mesh=mesh)) for _ in range(2)]
    svc.flush()
    for ti in t:
        svc.result(ti)
    st = svc.stats()
    assert st["n_requests"] == 2 and st["n_completed"] == 2
    assert st["latency_p99_s"] >= st["latency_p50_s"] > 0
    # the registry is the storage, labeled per instance
    assert obs.counter("service.requests",
                       service=svc.service_id).value == 2
    assert obs.counter("service.requests",
                       service=other.service_id).value == 0
    assert other.stats()["n_requests"] == 0
    assert obs.histogram("service.latency_s",
                         service=svc.service_id).count == 2


def test_executor_stats_publish_only_when_enabled(rng):
    from repro.core import engine

    obs.clear_metrics()
    p = engine.build_executor_plan(128, 128, 128, 4, 4, 4, 32)
    p.stats()
    assert len(obs.registry()) == 0  # gated: off by default
    obs.enable()
    st = p.stats()
    obs.disable()
    assert obs.counter("executor.stats_reports").value == 1
    assert obs.counter("executor.entries").value == st["n_entries"]
    assert obs.histogram("executor.occupancy").count == 1
