"""Shared pytest fixtures.

NOTE: XLA_FLAGS / device count is NOT set here — smoke tests and
benches see the default 1 device.  Multi-device distributed tests run
in subprocesses (tests/test_distributed.py) with their own XLA_FLAGS.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def run_subprocess_devices(code: str, n_devices: int = 16,
                           timeout: int = 600) -> str:
    """Run python ``code`` in a subprocess with n host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout
