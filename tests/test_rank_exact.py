"""Rank-exact execution (ISSUE 9): per-rank plans must be bitwise
interchangeable with the union-of-ranks plans they replace.

The distributed battery runs in one subprocess with 8 host devices
(conftest.run_subprocess_devices) and prints JSON; every algorithm x
mesh x pattern cell compares ``rank_exact=True`` against
``rank_exact=False`` on identical operands.  The load-balancing
permutation (sparsity.balance) is unit-tested in-process — it is pure
host-side numpy.
"""
import json

import numpy as np
import pytest

from conftest import run_subprocess_devices

from repro.sparsity.balance import (RebalancePlan, chunk_imbalance,
                                    chunk_loads, invert_permutation,
                                    permute_block_cols, permute_block_rows,
                                    plan_rebalance, retained_block_weights)

BATTERY = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul

rng = np.random.RandomState(0)
out = {}
bs = 8
nb = 8
n = nb * bs  # 64

grid = GridSpec("data", "model")
mesh11 = make_mesh((1, 1), ("data", "model"))
mesh22 = make_mesh((2, 2), ("data", "model"))
mesh41 = make_mesh((4, 1), ("data", "model"))
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
grid3 = GridSpec("data", "model", stack_axis="pod")
expand = lambda m: np.repeat(np.repeat(m, bs, 0), bs, 1)


def banded(nb, hw=1):
    idx = np.arange(nb)
    return np.abs(idx[:, None] - idx[None, :]) <= hw


def power_law(nb, fill=0.3, seed=3):
    r = np.random.RandomState(seed)
    p = (1.0 / (1.0 + np.arange(nb))) ** 1.2
    m = r.rand(nb, nb) < np.minimum(np.outer(p, p) * fill * nb, 1.0)
    np.fill_diagonal(m, True)
    return m


patterns = {
    "dense": np.ones((nb, nb), dtype=bool),
    "banded": banded(nb),
    "powerlaw": power_law(nb),
}

cases = [
    ("cannon@1x1", "cannon", mesh11, grid, {}),
    ("cannon@2x2", "cannon", mesh22, grid, {}),
    ("summa@2x2", "summa", mesh22, grid, {}),
    ("summa@4x1", "summa", mesh41, grid, {}),
    ("summa_gather@2x2", "summa", mesh22, grid, {"bcast": "gather"}),
    ("ts_k@2x2", "ts_k", mesh22, grid, {}),
    ("cannon25d@2x2x2", "cannon25d", mesh3, grid3, {}),
]

for pname, mask in patterns.items():
    A = rng.randn(n, n).astype(np.float32) * expand(mask)
    B = rng.randn(n, n).astype(np.float32) * expand(mask)
    for cname, algo, msh, grd, extra in cases:
        shd = NamedSharding(msh, P(grd.row_axis, grd.col_axis))
        Ad, Bd = jax.device_put(A, shd), jax.device_put(B, shd)
        kw = dict(mesh=msh, grid=grd, algorithm=algo, densify=False,
                  block_m=bs, block_k=bs, block_n=bs, local_kernel="ref",
                  pipeline_depth=1, a_mask=mask, b_mask=mask, **extra)
        Cu, pu = distributed_matmul(Ad, Bd, **kw, rank_exact=False,
                                    return_plan=True)
        Cr, pr_ = distributed_matmul(Ad, Bd, **kw, rank_exact=True,
                                     return_plan=True)
        key = f"{cname}/{pname}"
        out[key + "_bitwise"] = bool(
            np.array_equal(np.asarray(Cu), np.asarray(Cr)))
        eu, er = pu.executor_stats or {}, pr_.executor_stats or {}
        out[key + "_union_entries"] = int(eu.get("n_entries", 0))
        out[key + "_rank_entries"] = int(
            er.get("max_rank_entries", er.get("n_entries", 0)))
        out[key + "_collapsed"] = "max_rank_entries" not in er

# eps = 0 must be bitwise against the mask-only rank-exact run
mask = patterns["banded"]
A = rng.randn(n, n).astype(np.float32) * expand(mask)
B = rng.randn(n, n).astype(np.float32) * expand(mask)
shd = NamedSharding(mesh22, P("data", "model"))
Ad, Bd = jax.device_put(A, shd), jax.device_put(B, shd)
kw = dict(mesh=mesh22, grid=grid, algorithm="cannon", densify=False,
          block_m=bs, block_k=bs, block_n=bs, local_kernel="ref",
          pipeline_depth=1, a_mask=mask, b_mask=mask)
C0 = distributed_matmul(Ad, Bd, **kw)
C1 = distributed_matmul(Ad, Bd, **kw, filter_eps=0.0)
out["eps0_bitwise"] = bool(
    np.array_equal(np.asarray(C0), np.asarray(C1)))

# forced rebalance must round-trip the permutation: same product
# (summa keeps the k accumulation order rank-independent -> bitwise;
# cannon's start offset moves with the row rank -> allclose)
hot = np.zeros((nb, nb), dtype=bool)
hot[:2, :] = hot[:, :2] = True
np.fill_diagonal(hot, True)
A = rng.randn(n, n).astype(np.float32) * expand(hot)
B = rng.randn(n, n).astype(np.float32) * expand(hot)
Ad, Bd = jax.device_put(A, shd), jax.device_put(B, shd)
for algo, exact in (("summa", True), ("cannon", False)):
    kw = dict(mesh=mesh22, grid=grid, algorithm=algo, densify=False,
              block_m=bs, block_k=bs, block_n=bs, local_kernel="ref",
              pipeline_depth=1, a_mask=hot, b_mask=hot)
    C0 = np.asarray(distributed_matmul(Ad, Bd, **kw, rebalance=False))
    C1, pl = distributed_matmul(Ad, Bd, **kw, rebalance=True,
                                return_plan=True)
    C1 = np.asarray(C1)
    es = pl.executor_stats or {}
    out[f"rebalance_{algo}_applied"] = bool(es.get("rebalance_applied"))
    if exact:
        out[f"rebalance_{algo}_same"] = bool(np.array_equal(C0, C1))
    else:
        out[f"rebalance_{algo}_same"] = bool(
            np.allclose(C0, C1, rtol=1e-5, atol=5e-4))
    if es.get("rebalance_applied"):
        out[f"rebalance_{algo}_improved"] = bool(
            es.get("rebalance_imbalance_after", 9e9)
            < es.get("rebalance_imbalance_before", 0))

print("JSON" + json.dumps(out))
"""

CASES = ["cannon@1x1", "cannon@2x2", "summa@2x2", "summa@4x1",
         "summa_gather@2x2", "ts_k@2x2", "cannon25d@2x2x2"]
PATTERNS = ["dense", "banded", "powerlaw"]


@pytest.fixture(scope="module")
def battery():
    stdout = run_subprocess_devices(BATTERY, n_devices=8, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("case", CASES)
def test_rank_exact_bitwise_vs_union(battery, case, pattern):
    assert battery[f"{case}/{pattern}_bitwise"], \
        (case, pattern, "rank-exact product != union product")


@pytest.mark.parametrize("case", CASES)
def test_dense_collapses_to_union(battery, case):
    # uniform fill: every rank's plan is identical, so the executor must
    # collapse to the single shared plan (no per-rank slab dispatched)
    assert battery[f"{case}/dense_collapsed"], \
        (case, "dense multiply did not collapse to the union plan")


@pytest.mark.parametrize("case", ["cannon@2x2", "summa@2x2"])
def test_banded_busiest_rank_shrinks(battery, case):
    u = battery[f"{case}/banded_union_entries"]
    r = battery[f"{case}/banded_rank_entries"]
    assert u and r and r < u, (case, u, r)


def test_eps0_bitwise_under_rank_exact(battery):
    assert battery["eps0_bitwise"]


@pytest.mark.parametrize("algo", ["summa", "cannon"])
def test_rebalance_round_trip(battery, algo):
    assert battery[f"rebalance_{algo}_applied"], \
        (algo, "forced rebalance never applied a permutation")
    assert battery[f"rebalance_{algo}_same"], \
        (algo, "permuted execution changed the product")
    assert battery.get(f"rebalance_{algo}_improved", True)


# ---------------------------------------------------------------------------
# load-balance planning: pure host-side numpy, no devices needed
# ---------------------------------------------------------------------------


def test_invert_permutation_round_trip(rng):
    perm = rng.permutation(17)
    inv = invert_permutation(perm)
    assert np.array_equal(perm[inv], np.arange(17))
    assert np.array_equal(inv[perm], np.arange(17))


def test_permute_rows_cols_round_trip(rng):
    x = rng.randn(32, 48).astype(np.float32)
    pm, pn = rng.permutation(4), rng.permutation(6)
    y = permute_block_rows(x, pm, 8)
    y = permute_block_cols(y, pn, 8)
    z = permute_block_rows(y, invert_permutation(pm), 8)
    z = permute_block_cols(z, invert_permutation(pn), 8)
    assert np.array_equal(np.asarray(z), x)


def test_chunk_loads_and_imbalance():
    W = np.zeros((4, 4), dtype=np.int64)
    W[0, 0] = 8  # one hot chunk on a 2x2 decomposition
    loads = chunk_loads(W, 2, 2)
    assert loads.shape == (2, 2) and loads[0, 0] == 8 and loads.sum() == 8
    assert chunk_imbalance(W, 2, 2) == pytest.approx(4.0)
    assert chunk_imbalance(np.ones((4, 4)), 2, 2) == pytest.approx(1.0)
    assert chunk_imbalance(W, 1, 1) == 1.0  # single rank is never imbalanced


def test_retained_weights_respect_filtering():
    am = np.ones((4, 4), dtype=bool)
    bm = np.ones((4, 4), dtype=bool)
    W = retained_block_weights(am, bm)
    assert W.shape == (4, 4) and np.all(W == 4)
    an = np.full((4, 4), 1e-9)
    bn = np.full((4, 4), 1e-9)
    an[0, :] = bn[:, 0] = 1.0
    Wf = retained_block_weights(am, bm, an, bn, filter_eps=1e-6)
    assert Wf[0, 0] == 4 and Wf[1, 1] == 0


def test_plan_rebalance_uniform_is_identity():
    am = bm = np.ones((8, 8), dtype=bool)
    plan = plan_rebalance(am, bm, 2, 2)
    assert isinstance(plan, RebalancePlan) and plan.identity
    assert plan.imbalance_after == pytest.approx(plan.imbalance_before)


def test_plan_rebalance_reduces_hot_corner():
    nb = 8
    am = np.zeros((nb, nb), dtype=bool)
    am[:2, :] = am[:, :2] = True
    np.fill_diagonal(am, True)
    plan = plan_rebalance(am, am, 2, 2)
    assert not plan.identity
    assert plan.imbalance_after < plan.imbalance_before
    # the reported numbers must match a recomputation on permuted masks
    pm, pn = plan.perm_m, plan.perm_n
    W = retained_block_weights(am[pm], am[:, pn])
    assert chunk_imbalance(W, 2, 2) == pytest.approx(plan.imbalance_after)
