"""Cost-model planner: model sanity, plan cache, and auto-vs-fixed
oracle equivalence (the ISSUE-3 acceptance battery).

Model-level tests pin ``hw`` to DEFAULT_HARDWARE so they are
independent of whatever calibration file a previous bench run left
behind; the distributed battery runs in a 4-device subprocess like
test_distributed.py.
"""
import json

import numpy as np
import pytest

from conftest import run_subprocess_devices

from repro.core.tall_skinny import (DEFAULT_TS_RATIO, classify_shape,
                                    ts_classify_ratio)
from repro.kernels.smm.autotune import best_params_for, best_params_meta
from repro.planner import cost_model
from repro.planner.cost_model import (DEFAULT_HARDWARE, Problem,
                                      candidate_cost, ts_crossover_ratio)
from repro.planner.plan import plan_multiply

HW = DEFAULT_HARDWARE


# ---------------------------------------------------------------------------
# cost-model sanity
# ---------------------------------------------------------------------------


def test_cannon_cost_monotone_in_comm_volume():
    """Growing K grows Cannon's shifted volume (m*k + k*n)/pg and the
    predicted comm cost with it, monotonically."""
    costs = [candidate_cost(HW, Problem(1024, k, 1024, 64, 64, 64, 1.0,
                                        4, 2, 2), "cannon", True)
             for k in (1024, 2048, 4096, 8192)]
    assert all(c.feasible for c in costs)
    comms = [c.comm_s for c in costs]
    assert comms == sorted(comms) and comms[0] < comms[-1]
    totals = [c.total_s for c in costs]
    assert totals == sorted(totals)


def test_cannon_cost_scales_with_bandwidth():
    slow = HW.replace(bytes_per_s=HW.bytes_per_s / 10)
    prob = Problem(2048, 2048, 2048, 64, 64, 64, 1.0, 4, 2, 2)
    assert candidate_cost(slow, prob, "cannon", True).comm_s > \
        candidate_cost(HW, prob, "cannon", True).comm_s


def test_25d_beats_cannon_only_when_memory_allows():
    """2.5D halves the shift steps (cheaper) but replicates operands
    c-fold; when the replicas don't fit, the planner must fall back to
    plain Cannon — the model's memory gate is what decides."""
    hw = HW.replace(latency_s=1e-6, bytes_per_s=1e11)
    kw = dict(blocks=(64, 64, 64), mesh_shape=(4, 4, 2), densify=True)
    ample = plan_multiply(8192, 8192, 8192, hw=hw, **kw)
    assert ample.algorithm == "cannon25d" and ample.c_repl == 2
    c25 = next(c for c in ample.candidates if c.algorithm == "cannon25d")
    ca = next(c for c in ample.candidates if c.algorithm == "cannon")
    assert c25.total_s < ca.total_s
    assert c25.mem_bytes > ca.mem_bytes  # the replication charge

    # per-device 2D shards fit (~50 MB) but the 2.5D replicas (~84 MB)
    # do not -> cannon25d infeasible, cannon chosen
    tight = plan_multiply(8192, 8192, 8192,
                          hw=hw.replace(mem_bytes=60e6), **kw)
    assert tight.algorithm == "cannon"
    c25 = next(c for c in tight.candidates if c.algorithm == "cannon25d")
    assert not c25.feasible and "GB/device" in c25.reason


def test_tall_skinny_picked_for_8_to_1_shapes():
    for m, k, n, family in [(512, 4096, 512, "ts_k"),
                            (4096, 512, 512, "ts_m"),
                            (512, 512, 4096, "ts_n")]:
        plan = plan_multiply(m, k, n, blocks=(64, 64, 64),
                             mesh_shape=(2, 2), hw=HW)
        assert plan.algorithm == family, (m, k, n, plan.algorithm)


def test_forced_algorithm_and_path_are_honoured():
    plan = plan_multiply(1024, 1024, 1024, blocks=(64, 64, 64),
                         mesh_shape=(2, 2), algorithm="summa",
                         densify=False, hw=HW)
    assert plan.algorithm == "summa" and plan.densify is False
    assert plan.stack_tile is not None and plan.align is not None
    assert plan.params_source is not None


def test_explain_lists_candidates():
    plan = plan_multiply(1024, 1024, 1024, blocks=(64, 64, 64),
                         mesh_shape=(2, 2), hw=HW)
    text = plan.explain()
    assert text.startswith("plan:")
    for label in ("cannon+densified", "summa+blocked", "ts_k+densified"):
        assert label in text
    assert "infeasible" in text  # cannon25d on a 2D mesh


# ---------------------------------------------------------------------------
# plan cache + trivial plan (the _masks_empty short-circuit)
# ---------------------------------------------------------------------------


def test_plan_cache_second_call_zero_evaluations():
    kw = dict(blocks=(32, 32, 32), mesh_shape=(2, 2), occupancy=0.37,
              hw=HW)
    first = plan_multiply(640, 640, 640, **kw)
    before = cost_model.N_EVALS
    second = plan_multiply(640, 640, 640, **kw)
    assert cost_model.N_EVALS == before, "cache hit must not re-evaluate"
    assert second is first


def test_zero_occupancy_returns_trivial_plan_without_evaluations():
    before = cost_model.N_EVALS
    plan = plan_multiply(256, 256, 256, blocks=(16, 16, 16),
                         mesh_shape=(2, 2), occupancy=0.0, hw=HW)
    assert plan.trivial and plan.predicted_s == 0.0
    assert plan.candidates == ()
    assert cost_model.N_EVALS == before, \
        "empty product must not touch the cost model (divide-by-zero)"
    # the blocked path (which skips everything) is preferred when the
    # geometry admits it
    assert plan.densify is False


def test_blocked_cost_rejects_zero_occupancy():
    with pytest.raises(ValueError, match="occupancy"):
        candidate_cost(HW, Problem(256, 256, 256, 16, 16, 16, 0.0,
                                   4, 2, 2), "cannon", False)


# ---------------------------------------------------------------------------
# rank imbalance pricing + rebalance arming (ISSUE 9)
# ---------------------------------------------------------------------------


def test_rebalance_armed_on_imbalanced_blocked_plan():
    kw = dict(blocks=(64, 64, 64), mesh_shape=(2, 2), occupancy=0.05,
              densify=False, hw=HW)
    plan = plan_multiply(4096, 4096, 4096, **kw, rank_imbalance=4.0)
    assert plan.rank_imbalance == pytest.approx(4.0)
    assert plan.rebalance, "4x imbalance at 5% fill should arm rebalance"
    assert plan.rebalance_saved_s > plan.rebalance_cost_s > 0.0
    assert "imbal" in plan.explain()


def test_rebalance_declined_when_balanced():
    kw = dict(blocks=(64, 64, 64), mesh_shape=(2, 2), occupancy=0.05,
              densify=False, hw=HW)
    uniform = plan_multiply(4096, 4096, 4096, **kw, rank_imbalance=1.0)
    assert not uniform.rebalance and uniform.rebalance_saved_s == 0.0
    unknown = plan_multiply(4096, 4096, 4096, **kw)
    assert not unknown.rebalance, \
        "no imbalance estimate must mean no speculative permutation"
    # distinct imbalances must not collide in the plan cache
    assert unknown is not uniform


def test_imbalance_inflates_blocked_candidate_cost():
    prob = Problem(4096, 4096, 4096, 64, 64, 64, 0.05, 4, 2, 2)
    union = candidate_cost(HW, prob, "cannon", False)
    flat = candidate_cost(HW, prob, "cannon", False, rank_imbalance=1.0)
    skew = candidate_cost(HW, prob, "cannon", False, rank_imbalance=3.0)
    assert skew.total_s > flat.total_s, \
        "busiest-rank pricing should inflate the imbalanced candidate"
    # rank-exact pricing at 5% fill on 4 ranks undercuts the legacy
    # union inflation (1 - 0.95^4) even at 3x imbalance
    assert flat.total_s < skew.total_s < union.total_s
    dense_flat = candidate_cost(HW, prob, "cannon", True)
    dense_skew = candidate_cost(HW, prob, "cannon", True, rank_imbalance=3.0)
    assert dense_skew.total_s == pytest.approx(dense_flat.total_s), \
        "densified execution is occupancy-blind; imbalance must not price it"


# ---------------------------------------------------------------------------
# planner-owned classify threshold + winners-table metadata
# ---------------------------------------------------------------------------


def test_ts_classify_ratio_exported_and_consistent():
    ratio = ts_classify_ratio()
    assert 2.0 <= ratio <= 64.0
    # classification must agree with the exported threshold exactly
    for m, k, n in [(100, 150, 80), (64, 4096, 64), (63360,) * 3,
                    (1408, 1982464, 1408)]:
        algo = classify_shape(m, k, n)
        dims = {"m": m, "k": k, "n": n}
        big = max(dims, key=dims.get)
        others = max(v for kk, v in dims.items() if kk != big)
        assert (algo == f"ts_{big}") == (dims[big] >= ratio * others)
    # explicit ratio still overrides (legacy call sites)
    assert classify_shape(64, 512, 64, ratio=DEFAULT_TS_RATIO) == "ts_k"
    assert classify_shape(64, 500, 64, ratio=DEFAULT_TS_RATIO) == "cannon"


def test_ts_crossover_ratio_bounds():
    # clamped to [2, 64] (or the legacy 8.0 fallback) for any constants
    for hw in (HW,
               HW.replace(bytes_per_s=HW.bytes_per_s * 100),
               HW.replace(latency_s=1e-6, bytes_per_s=1e11),
               HW.replace(latency_s=0.0)):
        assert 2.0 <= ts_crossover_ratio(hw) <= 64.0
    # higher per-message latency penalises Cannon's O(pg) messages and
    # pulls the tall-skinny crossover in, never out
    slow_lat = HW.replace(latency_s=HW.latency_s * 100)
    assert ts_crossover_ratio(slow_lat) <= ts_crossover_ratio(HW)


def test_best_params_meta_provenance(tmp_path):
    # unknown geometry -> heuristic, with align/stack_tile equal to the
    # legacy tuple lookup
    meta = best_params_meta(99, 99, 99, str(tmp_path / "none.json"))
    assert meta["source"] == "heuristic"
    assert (meta["align"], meta["stack_tile"]) == \
        best_params_for(99, 99, 99, str(tmp_path / "none.json"))
    # recorded winners surface their key and measured throughput
    cache = {"64": {"best": {"align": True, "stack_tile": 4096,
                             "gflops": 12.5}}}
    path = tmp_path / "tab.json"
    path.write_text(json.dumps(cache))
    meta = best_params_meta(64, 64, 64, str(path))
    assert meta["source"] == "winners[64]" and meta["gflops"] == 12.5
    # sparse bin falls back through the dense entry
    meta = best_params_meta(64, 64, 64, str(path), fill=0.05)
    assert meta["source"] == "winners[64]" and meta["bin"] == 0.05
    # non-uniform geometry
    assert best_params_meta(32, 64, 32)["source"] == "heuristic-nonuniform"


# ---------------------------------------------------------------------------
# distributed battery: auto oracle-equivalence vs every fixed algorithm
# ---------------------------------------------------------------------------

BATTERY = r"""
import json
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul
from repro.core import dbcsr
from repro.planner import cost_model
from repro.planner.plan import plan_cache_info

rng = np.random.RandomState(0)
mesh = make_mesh((2, 2), ("data", "model"))
grid = GridSpec("data", "model")
sh = NamedSharding(mesh, P("data", "model"))
out = {}
M = K = N = 128
bs = 16
A = rng.randn(M, K).astype(np.float32)
B = rng.randn(K, N).astype(np.float32)

for fill in (1.0, 0.2):
    if fill < 1.0:
        am = rng.rand(M // bs, K // bs) < fill
        bm = rng.rand(K // bs, N // bs) < fill
        am[0, 0] = bm[0, 0] = True
        Az = A * np.repeat(np.repeat(am, bs, 0), bs, 1)
        Bz = B * np.repeat(np.repeat(bm, bs, 0), bs, 1)
    else:
        am = bm = None
        Az, Bz = A, B
    ref = Az @ Bz
    Ad, Bd = jax.device_put(Az, sh), jax.device_put(Bz, sh)
    kw = dict(mesh=mesh, grid=grid, block_m=bs, block_k=bs, block_n=bs,
              a_mask=am, b_mask=bm, local_kernel="ref")
    C_auto, plan = distributed_matmul(Ad, Bd, algorithm="auto",
                                      return_plan=True, **kw)
    tag = f"{fill:g}"
    out[f"auto_err_{tag}"] = float(np.max(np.abs(np.asarray(C_auto) - ref)))
    out[f"auto_algo_{tag}"] = plan.algorithm
    out[f"auto_densify_{tag}"] = plan.densify
    # every fixed algorithm, both local paths, must agree with auto
    for algo in ("cannon", "summa", "ts_k", "ts_m", "ts_n"):
        for dens in (True, False):
            C = distributed_matmul(Ad, Bd, algorithm=algo, densify=dens, **kw)
            out[f"{algo}_{'dens' if dens else 'blk'}_{tag}"] = float(
                np.max(np.abs(np.asarray(C) - ref)))

# repeated auto multiply: plan comes from the cache, zero evaluations
ev0 = cost_model.N_EVALS
hits0 = plan_cache_info().hits
C2, plan2 = distributed_matmul(Ad, Bd, algorithm="auto",
                               return_plan=True, **kw)
out["cache_evals_delta"] = cost_model.N_EVALS - ev0
out["cache_hits_delta"] = plan_cache_info().hits - hits0
out["cache_same_choice"] = (plan2.algorithm == plan.algorithm
                            and plan2.densify == plan.densify)

# dbcsr.multiply defaults through the planner and exposes the plan
Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=bs)
Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=bs)
Cm, pl = dbcsr.multiply(Am, Bm, mesh=mesh, return_plan=True)
out["dbcsr_err"] = float(np.max(np.abs(np.asarray(Cm.data) - A @ B)))
out["dbcsr_algo"] = pl.algorithm
out["dbcsr_last_plan_is_plan"] = Cm.last_plan is pl

# disjoint masks -> empty product -> trivial plan, zero C, no evals
za = np.zeros((M // bs, K // bs), bool); za[:, 0] = True
zb = np.zeros((K // bs, N // bs), bool); zb[1, :] = True
Azz = A * np.repeat(np.repeat(za, bs, 0), bs, 1)
Bzz = B * np.repeat(np.repeat(zb, bs, 0), bs, 1)
ev0 = cost_model.N_EVALS
C0, plan0 = distributed_matmul(
    jax.device_put(Azz, sh), jax.device_put(Bzz, sh), mesh=mesh, grid=grid,
    block_m=bs, block_k=bs, block_n=bs, a_mask=za, b_mask=zb,
    local_kernel="ref", algorithm="auto", return_plan=True)
out["trivial"] = plan0.trivial
out["trivial_evals"] = cost_model.N_EVALS - ev0
out["trivial_max"] = float(np.max(np.abs(np.asarray(C0))))

print("JSON" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def battery():
    stdout = run_subprocess_devices(BATTERY, n_devices=4, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


TOL = 2e-4


@pytest.mark.parametrize("fill", ["1", "0.2"])
def test_auto_matches_every_fixed_algorithm(battery, fill):
    assert battery[f"auto_err_{fill}"] < TOL
    for algo in ("cannon", "summa", "ts_k", "ts_m", "ts_n"):
        for path in ("dens", "blk"):
            key = f"{algo}_{path}_{fill}"
            assert battery[key] < TOL, (key, battery[key])


def test_auto_routed_through_planner(battery):
    # the planner picked a real algorithm (and a local path) per fill
    assert battery["auto_algo_1"] in ("cannon", "summa", "ts_k", "ts_m",
                                      "ts_n")
    assert battery["auto_algo_0.2"] in ("cannon", "summa", "ts_k", "ts_m",
                                        "ts_n")
    assert battery["dbcsr_algo"] == battery["auto_algo_1"]
    assert battery["dbcsr_err"] < TOL
    assert battery["dbcsr_last_plan_is_plan"]


def test_plan_cache_hit_in_dispatch_path(battery):
    assert battery["cache_evals_delta"] == 0
    assert battery["cache_hits_delta"] >= 1
    assert battery["cache_same_choice"]


def test_empty_product_trivial_plan_in_dispatch_path(battery):
    assert battery["trivial"] is True
    assert battery["trivial_evals"] == 0
    assert battery["trivial_max"] == 0.0
