"""Distributed-engine correctness on multi-device host meshes.

Each test spawns one subprocess with XLA_FLAGS host devices (the main
pytest process keeps the default 1 device, per the dry-run contract).
One subprocess runs a battery of checks and prints JSON; asserting on
the parsed output keeps the expensive startup to a single process per
battery.
"""
import json

import pytest

from conftest import run_subprocess_devices

BATTERY = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.core.cannon import cannon_matmul
from repro.core.cannon25d import cannon25d_matmul
from repro.core.tall_skinny import tall_skinny_matmul
from repro.core.summa import summa_matmul
from repro.core.multiply import distributed_matmul
from repro.core import dbcsr

rng = np.random.RandomState(0)
out = {}

mesh = make_mesh((4, 4), ("data", "model"))
grid = GridSpec("data", "model")
M, K, N = 128, 256, 192
A = rng.randn(M, K).astype(np.float32)
B = rng.randn(K, N).astype(np.float32)
sh = NamedSharding(mesh, P("data", "model"))
Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
ref = A @ B
err = lambda C: float(np.max(np.abs(np.asarray(C) - ref)))

out["cannon"] = err(jax.jit(lambda a, b: cannon_matmul(a, b, mesh=mesh, grid=grid))(Ad, Bd))
out["cannon_rolled"] = err(jax.jit(lambda a, b: cannon_matmul(
    a, b, mesh=mesh, grid=grid, double_buffer=False))(Ad, Bd))
out["summa_psum"] = err(jax.jit(lambda a, b: summa_matmul(a, b, mesh=mesh, grid=grid))(Ad, Bd))
out["summa_gather"] = err(jax.jit(lambda a, b: summa_matmul(
    a, b, mesh=mesh, grid=grid, bcast="gather"))(Ad, Bd))
out["auto_square"] = err(distributed_matmul(Ad, Bd, mesh=mesh, grid=grid))
out["blocked_ref"] = err(distributed_matmul(
    Ad, Bd, mesh=mesh, grid=grid, algorithm="cannon", densify=False,
    block_m=16, block_k=16, block_n=16, local_kernel="ref"))
out["blocked_smm"] = err(distributed_matmul(
    Ad, Bd, mesh=mesh, grid=grid, algorithm="cannon", densify=False,
    block_m=16, block_k=16, block_n=16, local_kernel="smm"))

# tall-skinny: K large (the paper's rectangular case); M divisible by
# the 16-device flattened grid for the reduce_scatter variant
Kbig = 2048
A2 = rng.randn(32, Kbig).astype(np.float32)
B2 = rng.randn(Kbig, 40).astype(np.float32)
A2d = jax.device_put(A2, NamedSharding(mesh, P(None, ("data", "model"))))
B2d = jax.device_put(B2, NamedSharding(mesh, P(("data", "model"), None)))
ref2 = A2 @ B2
for mode, red in [("all_reduce", "all_reduce"), ("reduce_scatter", "reduce_scatter")]:
    C = jax.jit(lambda a, b: tall_skinny_matmul(
        a, b, mesh=mesh, grid=grid, reduce=red))(A2d, B2d)
    out[f"ts_k_{red}"] = float(np.max(np.abs(np.asarray(C) - ref2)))

# ts_m / ts_n zero-communication variants
A3 = rng.randn(512, 32).astype(np.float32); B3 = rng.randn(32, 48).astype(np.float32)
A3d = jax.device_put(A3, NamedSharding(mesh, P(("data","model"), None)))
B3d = jax.device_put(B3, NamedSharding(mesh, P(None, None)))
C = jax.jit(lambda a, b: tall_skinny_matmul(a, b, mesh=mesh, grid=grid, mode="ts_m"))(A3d, B3d)
out["ts_m"] = float(np.max(np.abs(np.asarray(C) - A3 @ B3)))

# DBCSR api + block-sparse occupancy semantics
mask = np.ones((4, 8), bool); mask[1] = False; mask[:, 3] = False
Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32, block_mask=mask)
Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=32)
Cm = dbcsr.multiply(Am, Bm, mesh=mesh, algorithm="cannon")
A_masked = A * np.repeat(np.repeat(mask, 32, 0), 32, 1)
out["sparse_api"] = float(np.max(np.abs(np.asarray(Cm.data) - A_masked @ B)))
out["occupancy"] = Am.occupancy

# 2.5D on (2, 4, 4): pod axis as the replication stack
mesh3 = make_mesh((2, 4, 4), ("pod", "data", "model"))
grid3 = GridSpec("data", "model", stack_axis="pod")
sh3 = NamedSharding(mesh3, P("data", "model"))
A4d, B4d = jax.device_put(A, sh3), jax.device_put(B, sh3)
out["cannon25d_ar"] = err(jax.jit(lambda a, b: cannon25d_matmul(
    a, b, mesh=mesh3, grid=grid3))(A4d, B4d))
out["cannon25d_rs"] = err(jax.jit(lambda a, b: cannon25d_matmul(
    a, b, mesh=mesh3, grid=grid3, reduce="reduce_scatter"))(A4d, B4d))
out["auto_25d"] = err(distributed_matmul(A4d, B4d, mesh=mesh3, grid=grid3,
                                         algorithm="cannon25d"))

print("JSON" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def battery_results():
    stdout = run_subprocess_devices(BATTERY, n_devices=32, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


TOL = 2e-4


@pytest.mark.parametrize("key", [
    "cannon", "cannon_rolled", "summa_psum", "summa_gather", "auto_square",
    "blocked_ref", "blocked_smm", "ts_k_all_reduce", "ts_k_reduce_scatter",
    "ts_m", "sparse_api", "cannon25d_ar", "cannon25d_rs", "auto_25d",
])
def test_distributed_correctness(battery_results, key):
    assert battery_results[key] < TOL, (key, battery_results[key])


def test_sparse_occupancy(battery_results):
    # 4x8 mask with row 1 and col 3 cleared -> 21/32 blocks present
    assert abs(battery_results["occupancy"] - 21 / 32) < 1e-9
