"""Host-side core units: block layouts, Morton traversal, stack plans,
densify/undensify round trips — plus hypothesis property tests on the
system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.blocking import (BlockLayout, GridSpec, morton_order,
                                 block_cyclic_owner, ceil_div)
from repro.core.stacks import build_stacks, stack_statistics, STACK_SIZE
from repro.core.densify import to_blocks, from_blocks, densify, undensify
from repro.core.tall_skinny import classify_shape, ts_classify_ratio


def test_block_layout_basics():
    l = BlockLayout(128, 256, 32, 64)
    assert l.nblock_rows == 4 and l.nblock_cols == 4
    assert l.nblocks == 16
    with pytest.raises(ValueError):
        BlockLayout(100, 64, 32, 64)


def test_morton_order_is_permutation():
    for nr, nc in [(4, 4), (3, 5), (1, 7), (8, 2)]:
        order = morton_order(nr, nc)
        assert order.shape == (nr * nc, 2)
        flat = order[:, 0] * nc + order[:, 1]
        assert sorted(flat.tolist()) == list(range(nr * nc))


def test_morton_locality():
    """Z-order keeps consecutive entries close (cache-oblivious)."""
    order = morton_order(8, 8).astype(np.int64)
    jumps = np.abs(np.diff(order[:, 0])) + np.abs(np.diff(order[:, 1]))
    assert jumps.mean() < 2.5   # row-major would average ~2 too but with
    assert jumps.max() <= 8     # long 7-step row breaks; Z stays local


def test_build_stacks_dense_counts():
    a = BlockLayout(128, 128, 32, 32)
    b = BlockLayout(128, 64, 32, 32)
    plans = build_stacks(a, b, stack_size=10)
    stats = stack_statistics(plans)
    assert stats["n_multiplications"] == 4 * 4 * 2
    # c-runs (length nbk=4) are never split across stacks
    for p in plans:
        c = p.triples[:, 2]
        assert (np.diff(np.flatnonzero(np.r_[True, c[1:] != c[:-1]])) == 4).all() \
            or len(p.triples) <= 4


def test_stack_c_contiguity():
    """Each C block's updates form one contiguous run (kernel invariant)."""
    a = BlockLayout(64, 96, 16, 16)
    b = BlockLayout(96, 80, 16, 16)
    for p in build_stacks(a, b, stack_size=30):
        c = p.triples[:, 2]
        seen = set()
        prev = None
        for x in c.tolist():
            if x != prev:
                assert x not in seen, "C block revisited non-contiguously"
                seen.add(x)
                prev = x


def test_block_cyclic_owner():
    assert block_cyclic_owner(5, 7, 4, 4) == (1, 3)


@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 4),
       st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_to_from_blocks_roundtrip(nbr, nbc, bm, bn):
    x = np.arange(nbr * bm * nbc * bn, dtype=np.float32).reshape(
        nbr * bm, nbc * bn)
    blocks = to_blocks(jnp.asarray(x), bm, bn)
    assert blocks.shape == (nbr * nbc, bm, bn)
    back = from_blocks(blocks, nbr, nbc)
    np.testing.assert_array_equal(np.asarray(back), x)
    # densify on blocked payload == original dense matrix
    np.testing.assert_array_equal(np.asarray(densify(blocks, nbr, nbc)), x)
    np.testing.assert_array_equal(
        np.asarray(undensify(jnp.asarray(x), bm, bn)), np.asarray(blocks))


@given(st.integers(32, 4096), st.integers(32, 4096), st.integers(32, 4096))
@settings(max_examples=50, deadline=None)
def test_classify_shape_properties(m, k, n):
    # the threshold is planner-owned (cost-model crossover) and exported
    # as ts_classify_ratio(); classification must agree with it exactly
    ratio = ts_classify_ratio()
    assert 2.0 <= ratio <= 64.0
    algo = classify_shape(m, k, n)
    dims = {"m": m, "k": k, "n": n}
    if algo.startswith("ts_"):
        big = algo[3:]
        others = [v for kk, v in dims.items() if kk != big]
        assert dims[big] >= ratio * max(others)
    else:
        assert algo == "cannon"
        big = max(dims, key=dims.get)
        others = [v for kk, v in dims.items() if kk != big]
        assert dims[big] < ratio * max(others)
    # the legacy constant still works as an explicit override
    assert classify_shape(m, k, n, ratio=8.0) == \
        ("ts_" + max(dims, key=dims.get)
         if max(dims.values()) >= 8.0 * sorted(dims.values())[1]
         else "cannon")


def test_classify_paper_shapes():
    # paper section IV: square 63360^3 -> cannon; rectangular
    # 1408 x 1982464 x 1408 -> tall-skinny
    assert classify_shape(63360, 63360, 63360) == "cannon"
    assert classify_shape(1408, 1982464, 1408) == "ts_k"


@given(st.sampled_from([16, 22, 32, 64]),
       st.integers(1, 5), st.integers(1, 5), st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_stack_flops_invariant(bs, nbr, nbk, nbc):
    """Sum of stack flops == 2*M*K*N regardless of stack_size."""
    a = BlockLayout(nbr * bs, nbk * bs, bs, bs)
    b = BlockLayout(nbk * bs, nbc * bs, bs, bs)
    for stack_size in (7, STACK_SIZE):
        plans = build_stacks(a, b, stack_size=stack_size)
        total = sum(p.flops() for p in plans)
        assert total == 2 * a.rows * a.cols * b.cols
