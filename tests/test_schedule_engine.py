"""Schedule-engine acceptance battery (ISSUE 4).

Three layers:

1. **Oracle battery** (multi-device subprocess): the unified pipelined
   driver must be *bit-identical* at ``pipeline_depth=1`` to the
   pre-refactor per-algorithm step loops — which are preserved verbatim
   inside the subprocess as the oracle — for every algorithm x fill
   {dense, 50%, 5%} x mesh {1x1, 2x2, 4x1}, on both local paths
   (densified and blocked/stepwise).  ``pipeline_depth=2`` must agree
   numerically (allclose) with depth 1.
2. **Mask-slice property tests** (host-side): the per-step union mask
   slices emitted by the schedule builders (``cannon_step_masks`` /
   ``summa_step_masks`` / ``ts_step_masks``) must match a brute-force
   enumeration of every rank's present triples at every step.
3. **Ragged executor bins** (host-side): the size-binned stack executor
   must be bit-identical to the legacy looped dispatch, collapse to a
   single legacy-layout bin for uniform (dense) plans, and report the
   padding-FLOP savings.
"""
import json

import numpy as np
import pytest

from conftest import run_subprocess_devices

# ---------------------------------------------------------------------------
# 1. oracle battery: schedule engine vs the pre-refactor loops
# ---------------------------------------------------------------------------

# The subprocess embeds the PRE-REFACTOR step loops verbatim (from
# core/cannon.py, core/summa.py, core/tall_skinny.py before the schedule
# engine landed) as the bitwise oracle.  ``lm`` objects are shared
# between oracle and engine so both paths dispatch the identical local
# multiplies.
BATTERY = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, pvary, shard_map
from repro.core.blocking import GridSpec
from repro.core.cannon import (_default_local_matmul, _shift_perm,
                               _skew_perm, cannon_matmul, cannon_step_masks)
from repro.core.cannon25d import _skew25d_perm, cannon25d_matmul
from repro.core.summa import summa_matmul, summa_n_panels, summa_step_masks
from repro.core.tall_skinny import tall_skinny_matmul
from repro.core.multiply import _stepwise_blocked_lm, distributed_matmul
from repro.core.stacks import normalize_block_masks


# ---- pre-refactor loops, preserved verbatim as the oracle -------------

def legacy_cannon_local_steps(a_blk, b_blk, *, pg, row_axis, col_axis,
                              local_matmul, out_dtype, skew=True,
                              double_buffer=True, steps=None, step_offset=0):
    if skew:
        a_blk = jax.lax.ppermute(a_blk, (row_axis, col_axis), _skew_perm(pg, "a"))
        b_blk = jax.lax.ppermute(b_blk, (row_axis, col_axis), _skew_perm(pg, "b"))
    if step_offset:
        shift_a = [(j, (j - step_offset) % pg) for j in range(pg)]
        shift_b = [(i, (i - step_offset) % pg) for i in range(pg)]
        a_blk = jax.lax.ppermute(a_blk, col_axis, shift_a)
        b_blk = jax.lax.ppermute(b_blk, row_axis, shift_b)
    n_steps = pg if steps is None else steps
    c_blk = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), dtype=out_dtype)
    shift_a = _shift_perm(pg)
    shift_b = _shift_perm(pg)
    stepwise = bool(getattr(local_matmul, "stepwise", False))
    if double_buffer or stepwise:
        for t in range(n_steps):
            if t < n_steps - 1:
                a_nxt = jax.lax.ppermute(a_blk, col_axis, shift_a)
                b_nxt = jax.lax.ppermute(b_blk, row_axis, shift_b)
            part = (local_matmul(a_blk, b_blk, step=t) if stepwise
                    else local_matmul(a_blk, b_blk))
            if part is not None:
                c_blk = c_blk + part.astype(out_dtype)
            if t < n_steps - 1:
                a_blk, b_blk = a_nxt, b_nxt
    else:
        def body(_, carry):
            a_c, b_c, c_c = carry
            c_c = c_c + local_matmul(a_c, b_c).astype(out_dtype)
            a_c = jax.lax.ppermute(a_c, col_axis, shift_a)
            b_c = jax.lax.ppermute(b_c, row_axis, shift_b)
            return a_c, b_c, c_c
        c_blk = pvary(c_blk, (row_axis, col_axis))
        _, _, c_blk = jax.lax.fori_loop(0, n_steps, body, (a_blk, b_blk, c_blk))
    return c_blk


def legacy_cannon(a, b, *, mesh, grid, local_matmul, out_dtype=None,
                  double_buffer=True):
    pg = grid.validate_square(mesh)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    def body(a_blk, b_blk):
        c = legacy_cannon_local_steps(
            a_blk, b_blk, pg=pg, row_axis=grid.row_axis,
            col_axis=grid.col_axis, local_matmul=local_matmul,
            out_dtype=jnp.float32, double_buffer=double_buffer)
        return c.astype(out_dtype)
    spec = P(grid.row_axis, grid.col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec, spec),
                     out_specs=spec, check_vma=False)(a, b)


def legacy_cannon25d(a, b, *, mesh, grid, local_matmul, out_dtype=None):
    pg = grid.validate_square(mesh)
    c_repl = grid.stack_size(mesh)
    spr = pg // c_repl
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    axes3 = (grid.stack_axis, grid.row_axis, grid.col_axis)
    def body(a_blk, b_blk):
        a_blk = jax.lax.ppermute(a_blk, axes3, _skew25d_perm(pg, c_repl, spr, "a"))
        b_blk = jax.lax.ppermute(b_blk, axes3, _skew25d_perm(pg, c_repl, spr, "b"))
        c_partial = legacy_cannon_local_steps(
            a_blk, b_blk, pg=pg, row_axis=grid.row_axis,
            col_axis=grid.col_axis, local_matmul=local_matmul,
            out_dtype=jnp.float32, skew=False, steps=spr)
        return jax.lax.psum(c_partial, grid.stack_axis).astype(out_dtype)
    spec2d = P(grid.row_axis, grid.col_axis)
    return shard_map(body, mesh=mesh, in_specs=(spec2d, spec2d),
                     out_specs=spec2d, check_vma=False)(a, b)


def legacy_summa(a, b, *, mesh, grid, local_matmul, out_dtype=None):
    pr, pc = grid.grid_shape(mesh)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    row_ax, col_ax = grid.row_axis, grid.col_axis
    n_panels = summa_n_panels(pr, pc)
    stepwise = bool(getattr(local_matmul, "stepwise", False))
    empty_steps = getattr(local_matmul, "empty_steps", frozenset())
    def body(a_blk, b_blk):
        my_col = jax.lax.axis_index(col_ax)
        my_row = jax.lax.axis_index(row_ax)
        kl_a = a_blk.shape[1] * pc // n_panels
        kl_b = b_blk.shape[0] * pr // n_panels
        c = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)
        for p in range(n_panels):
            if p in empty_steps:
                continue
            col_owner = p * pc // n_panels
            row_owner = p * pr // n_panels
            a_off = (p % (n_panels // pc)) * kl_a if n_panels != pc else 0
            b_off = (p % (n_panels // pr)) * kl_b if n_panels != pr else 0
            a_panel = jax.lax.dynamic_slice_in_dim(a_blk, a_off, kl_a, axis=1)
            b_panel = jax.lax.dynamic_slice_in_dim(b_blk, b_off, kl_b, axis=0)
            a_panel = jnp.where(my_col == col_owner, a_panel, 0)
            a_panel = jax.lax.psum(a_panel, col_ax)
            b_panel = jnp.where(my_row == row_owner, b_panel, 0)
            b_panel = jax.lax.psum(b_panel, row_ax)
            part = (local_matmul(a_panel, b_panel, step=p) if stepwise
                    else local_matmul(a_panel, b_panel))
            if part is not None:
                c = c + part.astype(jnp.float32)
        return c.astype(out_dtype)
    spec = P(row_ax, col_ax)
    return shard_map(body, mesh=mesh, in_specs=(spec, spec),
                     out_specs=spec, check_vma=False)(a, b)


def legacy_ts_k(a, b, *, mesh, grid, local_matmul, out_dtype=None,
                reduce="all_reduce"):
    axes = (grid.row_axis, grid.col_axis)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    def body_k(a_blk, b_blk):
        partial = local_matmul(a_blk, b_blk).astype(jnp.float32)
        if reduce == "all_reduce":
            c = jax.lax.psum(partial, axes)
        else:
            c = jax.lax.psum_scatter(partial, axes, scatter_dimension=0,
                                     tiled=True)
        return c.astype(out_dtype)
    out_spec = P(None, None) if reduce == "all_reduce" else P(axes, None)
    return shard_map(body_k, mesh=mesh, in_specs=(P(None, axes), P(axes, None)),
                     out_specs=out_spec, check_vma=False)(a, b)


# ---- battery ----------------------------------------------------------

BLOCK = 8
out = {}
rng = np.random.RandomState(0)


def masked_operands(m, k, n, fill):
    A = rng.randn(m, k).astype(np.float32)
    B = rng.randn(k, n).astype(np.float32)
    if fill >= 1.0:
        return A, B, None, None
    am = rng.rand(m // BLOCK, k // BLOCK) < fill
    bm = rng.rand(k // BLOCK, n // BLOCK) < fill
    am[0, 0] = bm[0, 0] = True
    A = A * np.repeat(np.repeat(am, BLOCK, 0), BLOCK, 1)
    B = B * np.repeat(np.repeat(bm, BLOCK, 0), BLOCK, 1)
    return A, B, am, bm


def blocked_lm_for(algo, mesh, grid, m, k, n, am, bm):
    # the stepwise/blocked local multiply the dispatcher would build,
    # shared verbatim between oracle and engine
    pr, pc = grid.grid_shape(mesh)
    amn, bmn = normalize_block_masks(m // BLOCK, k // BLOCK, n // BLOCK,
                                     am, bm)
    kw = dict(block_m=BLOCK, block_k=BLOCK, block_n=BLOCK, kernel="ref")
    if algo in ("cannon", "cannon25d"):
        pg = pr
        c_repl = grid.stack_size(mesh) if algo == "cannon25d" else 1
        steps = [{"pair_mask": pm}
                 for pm in cannon_step_masks(amn, bmn, pg, c_repl)]
        return _stepwise_blocked_lm(m // pg, k // pg, n // pg,
                                    mask_steps=steps, **kw)
    assert algo == "summa"
    n_panels = summa_n_panels(pr, pc)
    steps = [{"a_mask": ua, "b_mask": ub}
             for ua, ub in summa_step_masks(amn, bmn, pr, pc, n_panels)]
    return _stepwise_blocked_lm(m // pr, k // n_panels, n // pc,
                                mask_steps=steps, **kw)


def run_case(tag, legacy_fn, engine_fn, depth2_fn, ref):
    c_legacy = np.asarray(legacy_fn())
    c_d1 = np.asarray(engine_fn())
    c_d2 = np.asarray(depth2_fn())
    out[tag + "/bitwise_d1"] = bool(np.array_equal(c_legacy, c_d1))
    out[tag + "/allclose_d2"] = bool(np.allclose(c_d1, c_d2, atol=1e-4))
    out[tag + "/err"] = float(np.max(np.abs(c_d1 - ref)))


MESHES = {
    "1x1": ((1, 1), ("data", "model")),
    "2x2": ((2, 2), ("data", "model")),
    "4x1": ((4, 1), ("data", "model")),
}

for mesh_name, (shape, axes) in MESHES.items():
    mesh = make_mesh(shape, axes)
    grid = GridSpec("data", "model")
    pr, pc = shape
    sh = NamedSharding(mesh, P("data", "model"))
    m = k = n = 64
    for fill in (1.0, 0.5, 0.05):
        A, B, am, bm = masked_operands(m, k, n, fill)
        Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
        ref = A @ B
        algos = ["summa"] + (["cannon"] if pr == pc else [])
        for algo in algos:
            legacy = legacy_cannon if algo == "cannon" else legacy_summa
            engine = cannon_matmul if algo == "cannon" else summa_matmul
            # densified path
            lm = _default_local_matmul(jax.lax.Precision.DEFAULT)
            run_case(
                f"{mesh_name}/{algo}/dens/{fill:g}",
                lambda: legacy(Ad, Bd, mesh=mesh, grid=grid, local_matmul=lm),
                lambda: engine(Ad, Bd, mesh=mesh, grid=grid, local_matmul=lm,
                               pipeline_depth=1),
                lambda: engine(Ad, Bd, mesh=mesh, grid=grid, local_matmul=lm,
                               pipeline_depth=2),
                ref)
            # blocked (stepwise when masked) path
            if am is None:
                blm = blocked_lm_for(algo, mesh, grid, m, k, n, None, None)
            else:
                blm = blocked_lm_for(algo, mesh, grid, m, k, n, am, bm)
            run_case(
                f"{mesh_name}/{algo}/blk/{fill:g}",
                lambda: legacy(Ad, Bd, mesh=mesh, grid=grid, local_matmul=blm),
                lambda: engine(Ad, Bd, mesh=mesh, grid=grid, local_matmul=blm,
                               pipeline_depth=1),
                lambda: engine(Ad, Bd, mesh=mesh, grid=grid, local_matmul=blm,
                               pipeline_depth=2),
                ref)

        # tall-skinny: K sharded over every device
        p_all = pr * pc
        Kbig = 64 * p_all
        A2, B2, _, _ = masked_operands(16, Kbig, 16, 1.0)
        A2d = jax.device_put(A2, NamedSharding(mesh, P(None, ("data", "model"))))
        B2d = jax.device_put(B2, NamedSharding(mesh, P(("data", "model"), None)))
        lm = _default_local_matmul(jax.lax.Precision.DEFAULT)
        ref2 = A2 @ B2
        run_case(
            f"{mesh_name}/ts_k/dens/{fill:g}",
            lambda: legacy_ts_k(A2d, B2d, mesh=mesh, grid=grid, local_matmul=lm),
            lambda: tall_skinny_matmul(A2d, B2d, mesh=mesh, grid=grid,
                                       mode="ts_k", reduce="all_reduce",
                                       local_matmul=lm, pipeline_depth=1),
            lambda: tall_skinny_matmul(A2d, B2d, mesh=mesh, grid=grid,
                                       mode="ts_k", reduce="all_reduce",
                                       local_matmul=lm, pipeline_depth=2),
            ref2)

# 2.5D on a (2, 2, 2) pod mesh
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
grid3 = GridSpec("data", "model", stack_axis="pod")
sh3 = NamedSharding(mesh3, P("data", "model"))
m = k = n = 64
for fill in (1.0, 0.5, 0.05):
    A, B, am, bm = masked_operands(m, k, n, fill)
    Ad, Bd = jax.device_put(A, sh3), jax.device_put(B, sh3)
    ref = A @ B
    lm = _default_local_matmul(jax.lax.Precision.DEFAULT)
    run_case(
        f"2x2x2/cannon25d/dens/{fill:g}",
        lambda: legacy_cannon25d(Ad, Bd, mesh=mesh3, grid=grid3, local_matmul=lm),
        lambda: cannon25d_matmul(Ad, Bd, mesh=mesh3, grid=grid3,
                                 local_matmul=lm, pipeline_depth=1),
        lambda: cannon25d_matmul(Ad, Bd, mesh=mesh3, grid=grid3,
                                 local_matmul=lm, pipeline_depth=2),
        ref)
    blm = blocked_lm_for("cannon25d", mesh3, grid3, m, k, n, am, bm)
    run_case(
        f"2x2x2/cannon25d/blk/{fill:g}",
        lambda: legacy_cannon25d(Ad, Bd, mesh=mesh3, grid=grid3, local_matmul=blm),
        lambda: cannon25d_matmul(Ad, Bd, mesh=mesh3, grid=grid3,
                                 local_matmul=blm, pipeline_depth=1),
        lambda: cannon25d_matmul(Ad, Bd, mesh=mesh3, grid=grid3,
                                 local_matmul=blm, pipeline_depth=2),
        ref)

# rolled ablation (depth 0) must match the legacy double_buffer=False loop
mesh = make_mesh((2, 2), ("data", "model"))
grid = GridSpec("data", "model")
sh = NamedSharding(mesh, P("data", "model"))
A, B, _, _ = masked_operands(64, 64, 64, 1.0)
Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
lm = _default_local_matmul(jax.lax.Precision.DEFAULT)
c_legacy = np.asarray(legacy_cannon(Ad, Bd, mesh=mesh, grid=grid,
                                    local_matmul=lm, double_buffer=False))
c_rolled = np.asarray(cannon_matmul(Ad, Bd, mesh=mesh, grid=grid,
                                    local_matmul=lm, pipeline_depth=0))
out["rolled/bitwise"] = bool(np.array_equal(c_legacy, c_rolled))

# auto dispatch carries the plan's depth and schedule stats
C, plan = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid, return_plan=True)
out["plan/depth_valid"] = plan.pipeline_depth in (1, 2)
out["plan/schedule_stats"] = bool(plan.schedule_stats
                                  and plan.schedule_stats["n_steps"] >= 1)

print("JSON" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def battery():
    stdout = run_subprocess_devices(BATTERY, n_devices=8, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


def test_depth1_bit_identical_to_legacy_loops(battery):
    bad = {k: v for k, v in battery.items()
           if k.endswith("/bitwise_d1") and v is not True}
    assert not bad, f"schedule engine diverged bitwise from legacy: {bad}"


def test_depth2_numerically_equivalent(battery):
    bad = {k: v for k, v in battery.items()
           if k.endswith("/allclose_d2") and v is not True}
    assert not bad, f"pipelined depth-2 diverged from depth-1: {bad}"


def test_engine_correct_vs_numpy(battery):
    bad = {k: v for k, v in battery.items()
           if k.endswith("/err") and v > 2e-4}
    assert not bad, f"schedule engine wrong vs numpy reference: {bad}"


def test_rolled_ablation_bit_identical(battery):
    assert battery["rolled/bitwise"] is True


def test_auto_plan_carries_schedule(battery):
    assert battery["plan/depth_valid"] and battery["plan/schedule_stats"]


# ---------------------------------------------------------------------------
# 2. mask-slice property tests: builders vs brute-force rank enumeration
# ---------------------------------------------------------------------------


def _random_masks(rng, nbr, nbk, nbc, fill):
    am = rng.rand(nbr, nbk) < fill
    bm = rng.rand(nbk, nbc) < fill
    return am, bm


@pytest.mark.parametrize("pg,c_repl", [(2, 1), (4, 1), (4, 2), (3, 1)])
@pytest.mark.parametrize("fill", [1.0, 0.4, 0.1])
def test_cannon_step_masks_match_per_rank_enumeration(pg, c_repl, fill):
    from repro.core.cannon import cannon_step_masks

    rng = np.random.RandomState(pg * 10 + int(fill * 10))
    lr, lk, lc = 2, 3, 2
    nbr, nbk, nbc = pg * lr, pg * lk, pg * lc
    am, bm = _random_masks(rng, nbr, nbk, nbc, fill)
    got = cannon_step_masks(am, bm, pg, c_repl)
    spr = pg // c_repl
    assert len(got) == spr

    want = [np.zeros((lr, lk, lc), dtype=bool) for _ in range(spr)]
    # brute force: every (replica p, rank (i, j), step t) holds A chunk
    # (i, q) and B chunk (q, j) with q = (i + j + p*spr + t) % pg; its
    # present local triples are the chunk-mask product
    for p in range(c_repl):
        for i in range(pg):
            for j in range(pg):
                for t in range(spr):
                    q = (i + j + p * spr + t) % pg
                    ac = am[i * lr:(i + 1) * lr, q * lk:(q + 1) * lk]
                    bc = bm[q * lk:(q + 1) * lk, j * lc:(j + 1) * lc]
                    want[t] |= ac[:, :, None] & bc[None, :, :]
    for t in range(spr):
        np.testing.assert_array_equal(got[t], want[t])


@pytest.mark.parametrize("pr,pc", [(2, 2), (4, 1), (2, 4), (3, 2)])
@pytest.mark.parametrize("fill", [1.0, 0.4, 0.1])
def test_summa_step_masks_match_per_rank_enumeration(pr, pc, fill):
    from repro.core.summa import summa_n_panels, summa_step_masks

    rng = np.random.RandomState(pr * 10 + pc + int(fill * 10))
    n_panels = summa_n_panels(pr, pc)
    lr, lc, lkp = 2, 2, 2
    nbr, nbc, nbk = pr * lr, pc * lc, n_panels * lkp
    am, bm = _random_masks(rng, nbr, nbk, nbc, fill)
    got = summa_step_masks(am, bm, pr, pc, n_panels)
    assert len(got) == n_panels
    for p in range(n_panels):
        ksl = slice(p * lkp, (p + 1) * lkp)
        # brute force over every (row rank, col rank) pair: the rank's
        # panel-p triples are its A row chunk x B col chunk product
        want = np.zeros((lr, lkp, lc), dtype=bool)
        for i in range(pr):
            for j in range(pc):
                ac = am[i * lr:(i + 1) * lr, ksl]
                bc = bm[ksl, j * lc:(j + 1) * lc]
                want |= ac[:, :, None] & bc[None, :, :]
        ua, ub = got[p]
        have = ua[:, :, None] & ub[None, :, :]
        # the factored union is SPMD-sound (covers every rank's triples)
        assert (want & ~have).sum() == 0
        # and row/col independence makes it exactly tight
        np.testing.assert_array_equal(have, want)


@pytest.mark.parametrize("mode", ["ts_k", "ts_m", "ts_n"])
@pytest.mark.parametrize("fill", [1.0, 0.3])
def test_ts_step_masks_match_per_rank_enumeration(mode, fill):
    from repro.core.tall_skinny import ts_step_masks

    rng = np.random.RandomState(
        {"ts_k": 11, "ts_m": 22, "ts_n": 33}[mode] + int(fill * 10))
    p_all = 4
    nbr, nbk, nbc = 4 * (p_all if mode == "ts_m" else 1), \
        4 * (p_all if mode == "ts_k" else 1), \
        4 * (p_all if mode == "ts_n" else 1)
    am, bm = _random_masks(rng, nbr, nbk, nbc, fill)
    got = ts_step_masks(mode, am, bm, p_all)
    if mode == "ts_k":
        lk = nbk // p_all
        want = np.zeros((nbr, lk, nbc), dtype=bool)
        for d in range(p_all):
            ac = am[:, d * lk:(d + 1) * lk]
            bc = bm[d * lk:(d + 1) * lk, :]
            want |= ac[:, :, None] & bc[None, :, :]
        np.testing.assert_array_equal(got["pair_mask"], want)
    elif mode == "ts_m":
        lr = nbr // p_all
        want = np.zeros((lr, nbk), dtype=bool)
        for d in range(p_all):
            want |= am[d * lr:(d + 1) * lr]
        np.testing.assert_array_equal(got["a_mask"], want)
        np.testing.assert_array_equal(got["b_mask"], bm)
    else:
        lc = nbc // p_all
        want = np.zeros((nbk, lc), dtype=bool)
        for d in range(p_all):
            want |= bm[:, d * lc:(d + 1) * lc]
        np.testing.assert_array_equal(got["a_mask"], am)
        np.testing.assert_array_equal(got["b_mask"], want)


# ---------------------------------------------------------------------------
# 3. ragged-aware (size-binned) stack executor
# ---------------------------------------------------------------------------


def test_dense_plan_single_bin_legacy_layout():
    from repro.core.engine import build_executor_plan

    plan = build_executor_plan(64, 64, 64, 8, 8, 8, 32)
    assert plan.n_bins == 1
    assert plan.n_padding == plan.n_padding_unbinned
    # legacy single-tensor view is the bin itself
    assert plan.triples is plan.bin_triples[0]


def test_ragged_plan_bins_cut_padding():
    import jax.numpy as jnp

    from repro.core.densify import from_blocks, to_blocks
    from repro.core.engine import (build_executor_plan, execute_plan,
                                   execute_plans_looped)

    rng = np.random.RandomState(3)
    block, nb = 8, 16
    dim = block * nb
    # row 0 of A dense (k-runs of nb per C block), the rest one k each:
    # with stack_size 8 the long runs become oversized single-run
    # stacks (size nb) while short runs pack 8 per stack — padding to
    # the longest would waste > 25% of the rows, so binning engages
    am = np.zeros((nb, nb), dtype=bool)
    am[0, :] = True
    am[1:, 0] = True
    bm = np.ones((nb, nb), dtype=bool)
    plan = build_executor_plan(dim, dim, dim, block, block, block, 8,
                               a_mask=am, b_mask=bm)
    assert 2 <= plan.n_bins <= 4
    assert plan.n_padding < plan.n_padding_unbinned
    assert plan.stats()["padding_triples_saved"] > 0
    stats = plan.stats()
    assert stats["padding_triples_saved"] == \
        plan.n_padding_unbinned - plan.n_padding
    assert stats["padding_flops_saved"] == \
        stats["padding_triples_saved"] * 2 * block ** 3

    a = rng.randn(dim, dim).astype(np.float32)
    b = rng.randn(dim, dim).astype(np.float32)
    af = a * np.repeat(np.repeat(am, block, 0), block, 1)
    bf = b * np.repeat(np.repeat(bm, block, 0), block, 1)
    ab = to_blocks(jnp.asarray(af), block, block)
    bb = to_blocks(jnp.asarray(bf), block, block)
    c0 = jnp.zeros((nb * nb, block, block), jnp.float32)
    c_binned = execute_plan(plan, ab, bb, c0, kernel="ref")
    c_looped = execute_plans_looped(list(plan.plans), ab, bb, c0,
                                    kernel="ref")
    # binned execution is bit-identical to the legacy looped dispatch
    assert np.array_equal(np.asarray(c_binned), np.asarray(c_looped))
    got = np.asarray(from_blocks(c_binned, nb, nb))
    np.testing.assert_allclose(got, af @ bf, atol=1e-4)


def test_resolve_pipeline_depth_semantics():
    from repro.core.schedule import resolve_pipeline_depth

    assert resolve_pipeline_depth(None) == 2
    assert resolve_pipeline_depth(None, True) == 2
    assert resolve_pipeline_depth(None, False) == 0
    assert resolve_pipeline_depth(1, False) == 1  # explicit depth wins
    assert resolve_pipeline_depth(0) == 0
    assert resolve_pipeline_depth(7) == 2  # clamped
    with pytest.raises(ValueError):
        resolve_pipeline_depth(-1)
