"""Norm-based on-the-fly filtering (ISSUE 5, repro.sparsity): block
norms + their pytree round-trip, the eps=0 bit-identity battery across
algorithms x meshes x fills, retained-triple monotonicity in eps, the
norm-product bound's safety (never drops a significant contribution),
the norm-predicted trivial-plan short-circuit, the configurable stack
executor bin cap, and the McWeeny purification workload's decaying
occupancy trace."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_subprocess_devices
from repro.core import dbcsr, engine
from repro.core.blocking import BlockLayout, GridSpec
from repro.core.cannon import cannon_step_norms
from repro.core.densify import blocked_local_matmul
from repro.core.multiply import _masks_empty
from repro.core.stacks import build_stacks
from repro.core.summa import summa_step_norms
from repro.launch.mesh import make_mesh
from repro.sparsity.filter import (count_retained_triples, product_mask,
                                   retained_pair_presence)
from repro.sparsity.norms import compute_block_norms, product_norm_bound


def _expand(mask, bs):
    return np.repeat(np.repeat(mask, bs, 0), bs, 1)


def _masked_norms(arr, mask, bs):
    norms = compute_block_norms(arr, bs, bs)
    return np.where(mask, norms, np.float32(0.0))


# ---------------------------------------------------------------------------
# norms: values, the product bound, pytree round-trips (satellite)
# ---------------------------------------------------------------------------


def test_block_norms_match_reference(rng):
    bs, nb = 8, 5
    A = rng.randn(nb * bs, nb * bs).astype(np.float32)
    norms = compute_block_norms(A, bs, bs)
    ref = np.array([[np.linalg.norm(A[i * bs:(i + 1) * bs,
                                      j * bs:(j + 1) * bs])
                     for j in range(nb)] for i in range(nb)])
    np.testing.assert_allclose(norms, ref, rtol=1e-5)
    assert norms.dtype == np.float32


def test_product_norm_bound_holds(rng):
    """||C_ij||_F <= sum_k ||A_ik|| * ||B_kj|| — the bound that makes
    the post-multiply mask predictable before executing."""
    bs, nb = 8, 4
    A = rng.randn(nb * bs, nb * bs).astype(np.float32)
    B = rng.randn(nb * bs, nb * bs).astype(np.float32)
    bound = product_norm_bound(compute_block_norms(A, bs, bs),
                               compute_block_norms(B, bs, bs))
    C = A @ B
    actual = np.array([[np.linalg.norm(C[i * bs:(i + 1) * bs,
                                         j * bs:(j + 1) * bs])
                        for j in range(nb)] for i in range(nb)])
    assert (actual <= bound * (1 + 1e-5)).all()


def test_block_norms_survive_pytree_roundtrip(rng):
    """Satellite: block_norms rebuilt through tree_unflatten aux data —
    the same mechanism PR 2 used for block_mask."""
    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    A = rng.randn(128, 128).astype(np.float32)
    mask = np.zeros((4, 4), bool)
    mask[0, :] = mask[:, 0] = True
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32,
                      block_mask=mask, compute_norms=True)
    assert Am.block_norms is not None
    # mask-absent blocks report norm 0
    assert (Am.block_norms[~mask] == 0).all()
    assert (Am.block_norms[mask] > 0).all()

    @jax.jit
    def scale(m: dbcsr.DBCSRMatrix) -> dbcsr.DBCSRMatrix:
        return m.scale(2.0)

    out = scale(Am)
    assert out.block_norms is not None
    # alpha=2.0 is concrete even under jit: norms rescale exactly and
    # the updated cache survives the output pytree
    np.testing.assert_allclose(out.block_norms, 2.0 * Am.block_norms,
                               rtol=1e-6)
    # explicit flatten/unflatten round-trip
    leaves, treedef = jax.tree_util.tree_flatten(Am)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(back.block_norms, Am.block_norms)
    np.testing.assert_array_equal(back.block_mask, mask)
    # norm-free matrices still round-trip with norms None
    Bm = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32)
    assert scale(Bm).block_norms is None
    # concrete-scalar scale rescales the cached norms exactly
    np.testing.assert_allclose(Am.scale(-3.0).block_norms,
                               3.0 * Am.block_norms, rtol=1e-6)


def test_norms_lazy_cache_and_filter(rng):
    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    A = rng.randn(64, 64).astype(np.float32)
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=16)
    assert Am.block_norms is None
    n1 = Am.norms()
    assert Am.block_norms is n1  # cached
    # filter(): drops every block below eps, zeroes payload, never
    # resurrects absent blocks
    eps = float(np.median(n1))
    F = Am.filter(eps)
    np.testing.assert_array_equal(F.block_mask, n1 >= eps)
    data = np.asarray(F.data)
    for i in range(4):
        for j in range(4):
            blk = data[i * 16:(i + 1) * 16, j * 16:(j + 1) * 16]
            assert (blk == 0).all() == (not F.block_mask[i, j])
    # filtering at a higher eps only shrinks the mask
    F2 = F.filter(eps * 2)
    assert (F2.block_mask <= F.block_mask).all()


# ---------------------------------------------------------------------------
# stack generation under eps (bit-identity, monotonicity, safety)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fill", [1.0, 0.5, 0.05])
def test_eps0_stacks_bit_identical(fill, rng):
    """filter_eps=0.0 must reproduce the mask-only enumeration exactly
    (same stacks, same triples) — the acceptance bit-identity contract
    at the Generation layer."""
    bs, nb = 8, 6
    la = BlockLayout(nb * bs, nb * bs, bs, bs)
    mask_rng = np.random.RandomState(int(fill * 100))
    am = bm = None
    if fill < 1.0:
        am = mask_rng.rand(nb, nb) < fill
        bm = mask_rng.rand(nb, nb) < fill
    A = rng.randn(nb * bs, nb * bs).astype(np.float32)
    B = rng.randn(nb * bs, nb * bs).astype(np.float32)
    an = compute_block_norms(A, bs, bs)
    bn = compute_block_norms(B, bs, bs)
    if am is not None:
        an, bn = np.where(am, an, 0), np.where(bm, bn, 0)
    base = build_stacks(la, la, 13, a_mask=am, b_mask=bm)
    filt = build_stacks(la, la, 13, a_mask=am, b_mask=bm,
                        a_norms=an, b_norms=bn, filter_eps=0.0)
    assert len(base) == len(filt)
    for p, q in zip(base, filt):
        np.testing.assert_array_equal(p.triples, q.triples)


def test_retained_triples_monotone_in_eps(rng):
    """Satellite property: retained triples non-increasing in eps, with
    the executor stats accounting for every dropped triple."""
    bs, nb = 8, 6
    m = nb * bs
    mask_rng = np.random.RandomState(3)
    am = mask_rng.rand(nb, nb) < 0.6
    bm = mask_rng.rand(nb, nb) < 0.6
    A = rng.randn(m, m).astype(np.float32)
    B = rng.randn(m, m).astype(np.float32)
    an, bn = _masked_norms(A, am, bs), _masked_norms(B, bm, bs)
    mask_triples = int((am.astype(np.int64) @ bm.astype(np.int64)).sum())
    prev = None
    for eps in [0.0, 1.0, 20.0, 50.0, 70.0, 100.0, 1e9]:
        plan = engine.build_executor_plan(
            m, m, m, bs, bs, bs, 64, a_mask=am, b_mask=bm,
            a_norms=an, b_norms=bn, filter_eps=eps)
        # count_retained_triples (the planner's occupancy numerator)
        # agrees with the plan the executor actually dispatches
        assert plan.n_entries == count_retained_triples(am, bm, an, bn, eps)
        assert plan.n_unfiltered_entries == mask_triples
        stats = plan.stats()
        assert stats["n_norm_filtered_triples"] == \
            mask_triples - plan.n_entries
        if prev is not None:
            assert plan.n_entries <= prev
        prev = plan.n_entries
    assert prev == 0  # eps=1e9 empties the product


def test_norm_bound_never_drops_significant_block(rng):
    """Safety: a triple whose TRUE contribution norm ||A_ik @ B_kj||_F
    is >= eps always survives the filter (submultiplicativity makes
    the product bound an over-approximation, never an under one)."""
    bs, nb = 8, 5
    m = nb * bs
    A = rng.randn(m, m).astype(np.float32)
    B = rng.randn(m, m).astype(np.float32)
    an = compute_block_norms(A, bs, bs)
    bn = compute_block_norms(B, bs, bs)
    for eps in [10.0, 50.0, 80.0]:
        plan = engine.build_executor_plan(
            m, m, m, bs, bs, bs, 64, a_norms=an, b_norms=bn,
            filter_eps=eps)
        retained = {tuple(t) for p in plan.plans for t in p.triples.tolist()}
        for i in range(nb):
            for k in range(nb):
                for j in range(nb):
                    true = np.linalg.norm(
                        A[i * bs:(i + 1) * bs, k * bs:(k + 1) * bs]
                        @ B[k * bs:(k + 1) * bs, j * bs:(j + 1) * bs])
                    if true >= eps:
                        assert (i * nb + k, k * nb + j, i * nb + j) \
                            in retained, (i, k, j, eps)


def test_filtered_executor_matches_dropped_triple_reference(rng):
    """The filtered executor computes exactly the sum of retained
    contributions (not an approximation of it)."""
    bs, nb = 8, 5
    m = nb * bs
    mask_rng = np.random.RandomState(7)
    am = mask_rng.rand(nb, nb) < 0.7
    bm = mask_rng.rand(nb, nb) < 0.7
    A = rng.randn(m, m).astype(np.float32) * _expand(am, bs)
    B = rng.randn(m, m).astype(np.float32) * _expand(bm, bs)
    an, bn = _masked_norms(A, am, bs), _masked_norms(B, bm, bs)
    eps = 60.0
    f = blocked_local_matmul(m, m, m, block_m=bs, block_k=bs, block_n=bs,
                             kernel="ref", a_mask=am, b_mask=bm,
                             a_norms=an, b_norms=bn, filter_eps=eps)
    plan = f.executor_plan
    assert 0 < plan.n_entries < plan.n_unfiltered_entries  # partial drop
    C = np.asarray(f(jnp.asarray(A), jnp.asarray(B)))
    keep = retained_pair_presence(am, bm, an, bn, eps)
    ref = np.zeros((m, m), np.float32)
    for i in range(nb):
        for k in range(nb):
            for j in range(nb):
                if keep[i, k, j]:
                    ref[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] += \
                        A[i * bs:(i + 1) * bs, k * bs:(k + 1) * bs] \
                        @ B[k * bs:(k + 1) * bs, j * bs:(j + 1) * bs]
    np.testing.assert_allclose(C, ref, rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# step-norm builders: SPMD union-of-max semantics
# ---------------------------------------------------------------------------


def test_cannon_step_norms_union_of_max(rng):
    """Brute force: at each step the built tensor is the max over all
    (i, j) ranks of that rank's chunk norm products — so eps drops a
    triple only when it is sub-eps on EVERY rank."""
    pg, lb = 2, 3
    nb = pg * lb
    an = np.abs(rng.randn(nb, nb)).astype(np.float32)
    bn = np.abs(rng.randn(nb, nb)).astype(np.float32)
    steps = cannon_step_norms(an, bn, pg)
    assert len(steps) == pg
    for t, built in enumerate(steps):
        ref = np.zeros((lb, lb, lb))
        for i in range(pg):
            for j in range(pg):
                q = (i + j + t) % pg
                ac = an[i * lb:(i + 1) * lb, q * lb:(q + 1) * lb]
                bc = bn[q * lb:(q + 1) * lb, j * lb:(j + 1) * lb]
                ref = np.maximum(ref, ac[:, :, None] * bc[None, :, :])
        np.testing.assert_allclose(built, ref, rtol=1e-6)


def test_summa_step_norms_factored_max(rng):
    pr = pc = 2
    nb = 4
    an = np.abs(rng.randn(nb, nb)).astype(np.float32)
    bn = np.abs(rng.randn(nb, nb)).astype(np.float32)
    panels = summa_step_norms(an, bn, pr, pc, 2)
    assert len(panels) == 2
    for p, (ua, ub) in enumerate(panels):
        ksl = slice(p * 2, (p + 1) * 2)
        np.testing.assert_allclose(
            ua, np.maximum(an[:2, ksl], an[2:, ksl]), rtol=1e-6)
        np.testing.assert_allclose(
            ub, np.maximum(bn[ksl, :2], bn[ksl, 2:]), rtol=1e-6)


def test_masks_empty_fires_on_norm_filtered_steps():
    """Satellite (planner bugfix): eps filtering can empty a step (or a
    whole product) whose binary masks are non-empty — _masks_empty must
    see it so the trivial-plan short-circuit / step skipping fires."""
    am = np.ones((4, 4), bool)
    an = np.full((4, 4), 1e-4, np.float32)
    pair = am[:, :, None] & am[None, :, :]
    pn = (an[:, :, None] * an[None, :, :]).astype(np.float32)
    # mask-non-empty, all norm products 1e-8 < eps=1e-6 -> empty
    assert not _masks_empty({"pair_mask": pair})
    assert _masks_empty({"pair_mask": pair, "pair_norms": pn,
                         "filter_eps": 1e-6})
    # eps=0 never empties anything
    assert not _masks_empty({"pair_mask": pair, "pair_norms": pn,
                             "filter_eps": 0.0})
    # factored form
    assert _masks_empty({"a_mask": am, "b_mask": am, "a_norms": an,
                         "b_norms": an, "filter_eps": 1e-6})
    assert not _masks_empty({"a_mask": am, "b_mask": am, "a_norms": an,
                             "b_norms": an, "filter_eps": 1e-9})


def test_trivial_plan_on_norm_predicted_empty(rng):
    """A product whose binary masks are non-empty but whose every norm
    product is below eps short-circuits to the planner's trivial plan
    and executes as exact zeros."""
    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    A = (rng.randn(64, 64) * 1e-5).astype(np.float32)
    B = (rng.randn(64, 64) * 1e-5).astype(np.float32)
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=16)
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=16)
    C, plan = dbcsr.multiply(Am, Bm, mesh=mesh, filter_eps=1e-6,
                             return_plan=True)
    assert plan.trivial and plan.occupancy == 0.0
    assert (np.asarray(C.data) == 0).all()
    assert C.block_mask is not None and not C.block_mask.any()
    # the same operands multiply normally without the filter
    C2, plan2 = dbcsr.multiply(Am, Bm, mesh=mesh, return_plan=True)
    assert not plan2.trivial
    np.testing.assert_allclose(np.asarray(C2.data), A @ B,
                               rtol=0, atol=1e-6)


def test_product_mask_is_retained_support(rng):
    bs, nb = 8, 6
    m = nb * bs
    mask_rng = np.random.RandomState(5)
    am = mask_rng.rand(nb, nb) < 0.5
    bm = mask_rng.rand(nb, nb) < 0.5
    A = rng.randn(m, m).astype(np.float32) * _expand(am, bs)
    B = rng.randn(m, m).astype(np.float32) * _expand(bm, bs)
    an, bn = _masked_norms(A, am, bs), _masked_norms(B, bm, bs)
    for eps in [None, 0.0, 40.0, 1e9]:
        pm = product_mask(am, bm, an, bn, eps)
        keep = retained_pair_presence(am, bm, an, bn, eps)
        np.testing.assert_array_equal(pm, keep.any(axis=1))
    # eps None / 0.0 reduce to the symbolic mask product
    np.testing.assert_array_equal(
        product_mask(am, bm, an, bn, 0.0),
        (am.astype(np.int64) @ bm.astype(np.int64)) > 0)


# ---------------------------------------------------------------------------
# configurable stack-executor bin cap (satellite)
# ---------------------------------------------------------------------------


def _ragged_masks():
    mask_rng = np.random.RandomState(11)
    am = mask_rng.rand(16, 16) < 0.12
    bm = mask_rng.rand(16, 16) < 0.12
    am[0, :] = True  # one dense row -> wildly ragged run lengths
    return am, bm


def test_stack_bins_kwarg_and_env(monkeypatch):
    am, bm = _ragged_masks()
    m = 16 * 8
    kw = dict(a_mask=am, b_mask=bm)
    default = engine.build_executor_plan(m, m, m, 8, 8, 8, 64, **kw)
    assert 1 < default.n_bins <= 4
    single = engine.build_executor_plan(m, m, m, 8, 8, 8, 64, **kw,
                                        stack_bins=1)
    assert single.n_bins == 1
    # bins only refine: same entries, padding never worse than unbinned
    assert single.n_entries == default.n_entries
    assert default.n_padding <= single.n_padding
    wide = engine.build_executor_plan(m, m, m, 8, 8, 8, 64, **kw,
                                      stack_bins=8)
    assert default.n_bins <= wide.n_bins <= 8
    assert wide.n_padding <= default.n_padding
    # the env knob reaches the same resolution path
    monkeypatch.setenv("DBCSR_STACK_BINS", "1")
    assert engine.resolve_stack_bins() == 1
    env_plan = engine.build_executor_plan(m, m, m, 8, 8, 8, 64, **kw)
    assert env_plan.n_bins == 1
    monkeypatch.delenv("DBCSR_STACK_BINS")
    assert engine.resolve_stack_bins() == 4
    with pytest.raises(ValueError):
        engine.resolve_stack_bins(0)


def test_stack_bins_distinct_memo_entries():
    """stack_bins participates in the plan memo key — a bin-cap sweep
    must not serve one cap's layout for another."""
    am, bm = _ragged_masks()
    m = 16 * 8
    p1 = engine.build_executor_plan(m, m, m, 8, 8, 8, 64,
                                    a_mask=am, b_mask=bm, stack_bins=1)
    p4 = engine.build_executor_plan(m, m, m, 8, 8, 8, 64,
                                    a_mask=am, b_mask=bm, stack_bins=4)
    assert p1 is not p4 and p1.n_bins != p4.n_bins


# ---------------------------------------------------------------------------
# eps=0 bit-identity battery: algorithms x meshes x fills (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["cannon", "summa", "ts_k"])
@pytest.mark.parametrize("fill", [1.0, 0.5, 0.05])
def test_eps0_bit_identity_1x1(algo, fill, rng):
    from repro.core.multiply import distributed_matmul

    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    bs, nb = 8, 6
    m = nb * bs
    am = bm = None
    if fill < 1.0:
        mask_rng = np.random.RandomState(int(fill * 100))
        am = mask_rng.rand(nb, nb) < fill
        bm = mask_rng.rand(nb, nb) < fill
        am[0, 0] = bm[0, 0] = True
    A = rng.randn(m, m).astype(np.float32)
    B = rng.randn(m, m).astype(np.float32)
    if am is not None:
        A, B = A * _expand(am, bs), B * _expand(bm, bs)
    kw = dict(mesh=mesh, grid=grid, algorithm=algo, densify=False,
              block_m=bs, block_k=bs, block_n=bs, local_kernel="ref",
              a_mask=am, b_mask=bm)
    C0 = distributed_matmul(jnp.asarray(A), jnp.asarray(B), **kw)
    C1 = distributed_matmul(jnp.asarray(A), jnp.asarray(B), **kw,
                            filter_eps=0.0)
    assert np.array_equal(np.asarray(C0), np.asarray(C1)), \
        f"{algo}@{fill}: eps=0 not bit-identical"


FILTER_BATTERY = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul

rng = np.random.RandomState(0)
out = {}
bs = 8
grid = GridSpec("data", "model")
mesh = make_mesh((2, 2), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
grid3 = GridSpec("data", "model", stack_axis="pod")
sh3 = NamedSharding(mesh3, P("data", "model"))
expand = lambda m: np.repeat(np.repeat(m, bs, 0), bs, 1)

M = K = N = 64
nb = M // bs
for fill in (1.0, 0.5, 0.05):
    am = bm = None
    A = rng.randn(M, K).astype(np.float32)
    B = rng.randn(K, N).astype(np.float32)
    if fill < 1.0:
        am = rng.rand(nb, nb) < fill
        bm = rng.rand(nb, nb) < fill
        am[0, 0] = bm[0, 0] = True
        A *= expand(am); B *= expand(bm)
    cases = [("cannon", mesh, grid, sh, {}),
             ("summa", mesh, grid, sh, {}),
             ("summa_gather", mesh, grid, sh, {"bcast": "gather"}),
             ("ts_k", mesh, grid, sh, {}),
             ("cannon25d", mesh3, grid3, sh3, {})]
    for name, msh, grd, shd, extra in cases:
        algo = "summa" if name.startswith("summa") else name
        Ad, Bd = jax.device_put(A, shd), jax.device_put(B, shd)
        kw = dict(mesh=msh, grid=grd, algorithm=algo, densify=False,
                  block_m=bs, block_k=bs, block_n=bs, local_kernel="ref",
                  a_mask=am, b_mask=bm, **extra)
        C0 = np.asarray(distributed_matmul(Ad, Bd, **kw))
        C1 = np.asarray(distributed_matmul(Ad, Bd, **kw, filter_eps=0.0))
        out[f"{name}@{fill}_bitwise"] = bool(np.array_equal(C0, C1))
        # eps > 0: dropped contributions bounded by nbk * eps per block
        eps = 10.0
        C2 = np.asarray(distributed_matmul(Ad, Bd, **kw, filter_eps=eps))
        err = float(np.max(np.abs(C2 - A @ B)))
        out[f"{name}@{fill}_eps_err"] = err
        out[f"{name}@{fill}_eps_ok"] = bool(err <= nb * eps + 1e-3)
print("JSON" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def filter_battery():
    stdout = run_subprocess_devices(FILTER_BATTERY, n_devices=8, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


@pytest.mark.parametrize("algo", ["cannon", "summa", "summa_gather",
                                  "ts_k", "cannon25d"])
@pytest.mark.parametrize("fill", [1.0, 0.5, 0.05])
def test_eps0_bit_identity_battery(filter_battery, algo, fill):
    assert filter_battery[f"{algo}@{fill}_bitwise"], \
        (algo, fill, "filter_eps=0.0 changed bits")


@pytest.mark.parametrize("algo", ["cannon", "summa", "summa_gather",
                                  "ts_k", "cannon25d"])
@pytest.mark.parametrize("fill", [1.0, 0.5, 0.05])
def test_eps_error_bounded_battery(filter_battery, algo, fill):
    assert filter_battery[f"{algo}@{fill}_eps_ok"], \
        (algo, fill, filter_battery[f"{algo}@{fill}_eps_err"])


# ---------------------------------------------------------------------------
# purification workload (single device keeps it fast; the 4-device run
# is examples/purification.py)
# ---------------------------------------------------------------------------


def test_mcweeny_purification_occupancy_decays():
    from repro.sparsity.workloads import (banded_hamiltonian,
                                          initial_density, mcweeny_purify)

    n, bs = 128, 16
    H, mask = banded_hamiltonian(n, bs, half_bandwidth=3)
    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    P0 = dbcsr.create(initial_density(H).astype(np.float32), mesh=mesh,
                      grid=grid, block_size=bs, block_mask=mask)
    P, trace = mcweeny_purify(
        P0, mesh=mesh, n_iter=8, filter_eps=1e-6,
        multiply_kw=dict(densify=False, local_kernel="ref"))
    occs = [t["occupancy"] for t in trace]
    peak = occs.index(max(occs))
    assert all(occs[i + 1] <= occs[i] + 1e-12
               for i in range(peak, len(occs) - 1)), occs
    assert occs[-1] < occs[0], occs  # net sparsification
    assert trace[-1]["idempotency"] < 1e-4  # converged to a projector
    assert abs(trace[-1]["trace_P"] - n // 2) < 0.5  # electrons conserved
    # the filter actually dropped work somewhere along the run
    assert any(t.get("n_norm_filtered_triples", 0) > 0 for t in trace)
