"""DBCSRMatrix API semantics (single-device: the ops are mesh-agnostic;
the distributed multiply itself is covered by test_distributed.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dbcsr
from repro.core.blocking import GridSpec
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def setup(rng):
    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    A = rng.randn(128, 128).astype(np.float32)
    B = rng.randn(128, 128).astype(np.float32)
    return mesh, grid, A, B


def test_create_and_roundtrip(setup, rng):
    mesh, grid, A, B = setup
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32)
    np.testing.assert_array_equal(np.asarray(Am.data), A)
    assert Am.layout.nblocks == 16
    assert Am.occupancy == 1.0


def test_add_trace_transpose_scale(setup):
    mesh, grid, A, B = setup
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32)
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=32)
    np.testing.assert_allclose(np.asarray(dbcsr.add(Am, Bm).data), A + B,
                               rtol=1e-6)
    np.testing.assert_allclose(float(dbcsr.trace(Am)), np.trace(A), rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(dbcsr.transpose(Am).data), A.T)
    np.testing.assert_allclose(np.asarray(Am.scale(2.5).data), 2.5 * A,
                               rtol=1e-6)


def test_multiply_vector(setup, rng):
    mesh, grid, A, B = setup
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32)
    x = jnp.asarray(rng.randn(128).astype(np.float32))
    np.testing.assert_allclose(np.asarray(dbcsr.multiply_vector(Am, x)),
                               A @ np.asarray(x), rtol=1e-4, atol=1e-4)


def test_block_sparse_semantics(setup):
    mesh, grid, A, B = setup
    mask = np.zeros((4, 4), bool)
    mask[0, :] = True
    mask[:, 0] = True
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32, block_mask=mask)
    assert abs(Am.occupancy - 7 / 16) < 1e-9
    dense_mask = np.repeat(np.repeat(mask, 32, 0), 32, 1)
    np.testing.assert_array_equal(np.asarray(Am.data), A * dense_mask)
    # sparse x sparse result mask = boolean matmul of the masks
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=32, block_mask=mask)
    Cm = dbcsr.multiply(Am, Bm, mesh=mesh, algorithm="cannon")
    expected_mask = (mask.astype(int) @ mask.astype(int)) > 0
    np.testing.assert_array_equal(Cm.block_mask, expected_mask)


def test_block_mask_survives_jit_roundtrip(setup):
    """The pytree aux carries (shape, bytes) of the mask, so block
    sparsity must survive jit (tree_unflatten used to rebuild None)."""
    mesh, grid, A, B = setup
    mask = np.zeros((4, 4), bool)
    mask[0, :] = True
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32, block_mask=mask)

    @jax.jit
    def scale(m: dbcsr.DBCSRMatrix) -> dbcsr.DBCSRMatrix:
        return m.scale(2.0)

    out = scale(Am)
    assert out.block_mask is not None
    np.testing.assert_array_equal(out.block_mask, mask)
    np.testing.assert_allclose(np.asarray(out.data), np.asarray(Am.data) * 2,
                               rtol=1e-6)
    # explicit flatten/unflatten round-trip too
    leaves, treedef = jax.tree_util.tree_flatten(Am)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(back.block_mask, mask)
    # dense matrices still round-trip with no mask
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=32)
    assert scale(Bm).block_mask is None


def test_multiply_single_masked_operand_mask_flows(setup):
    """multiply() with exactly one masked operand: the symbolic product
    mask (missing mask treated as all-present) lands on the result and
    matches the numeric block support; add() stays dense (documented)."""
    mesh, grid, A, B = setup
    mask = np.zeros((4, 4), bool)
    mask[0, :] = True
    mask[2, 1] = True
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32, block_mask=mask)
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=32)
    Cm = dbcsr.multiply(Am, Bm, mesh=mesh, algorithm="cannon")
    expected = (mask.astype(np.int64) @ np.ones((4, 4), np.int64)) > 0
    np.testing.assert_array_equal(Cm.block_mask, expected)
    # symbolic mask == numeric support (random data: no exact cancels)
    Cb = np.asarray(Cm.data).reshape(4, 32, 4, 32)
    support = np.abs(Cb).max(axis=(1, 3)) > 0
    np.testing.assert_array_equal(support, expected)
    # blocked sparse path agrees with the densified product
    Cm_blocked = dbcsr.multiply(Am, Bm, mesh=mesh, algorithm="cannon",
                                densify=False, local_kernel="ref")
    np.testing.assert_allclose(np.asarray(Cm_blocked.data),
                               np.asarray(Cm.data), rtol=0, atol=1e-3)
    # add: union with a dense operand is dense -> mask is None
    assert dbcsr.add(Am, Bm).block_mask is None
    np.testing.assert_array_equal(dbcsr.add(Am, Am).block_mask, mask)
