"""DBCSRMatrix API semantics (single-device: the ops are mesh-agnostic;
the distributed multiply itself is covered by test_distributed.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dbcsr
from repro.core.blocking import GridSpec
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def setup(rng):
    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    A = rng.randn(128, 128).astype(np.float32)
    B = rng.randn(128, 128).astype(np.float32)
    return mesh, grid, A, B


def test_create_and_roundtrip(setup, rng):
    mesh, grid, A, B = setup
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32)
    np.testing.assert_array_equal(np.asarray(Am.data), A)
    assert Am.layout.nblocks == 16
    assert Am.occupancy == 1.0


def test_add_trace_transpose_scale(setup):
    mesh, grid, A, B = setup
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32)
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=32)
    np.testing.assert_allclose(np.asarray(dbcsr.add(Am, Bm).data), A + B,
                               rtol=1e-6)
    np.testing.assert_allclose(float(dbcsr.trace(Am)), np.trace(A), rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(dbcsr.transpose(Am).data), A.T)
    np.testing.assert_allclose(np.asarray(Am.scale(2.5).data), 2.5 * A,
                               rtol=1e-6)


def test_multiply_vector(setup, rng):
    mesh, grid, A, B = setup
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32)
    x = jnp.asarray(rng.randn(128).astype(np.float32))
    np.testing.assert_allclose(np.asarray(dbcsr.multiply_vector(Am, x)),
                               A @ np.asarray(x), rtol=1e-4, atol=1e-4)


def test_block_sparse_semantics(setup):
    mesh, grid, A, B = setup
    mask = np.zeros((4, 4), bool)
    mask[0, :] = True
    mask[:, 0] = True
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=32, block_mask=mask)
    assert abs(Am.occupancy - 7 / 16) < 1e-9
    dense_mask = np.repeat(np.repeat(mask, 32, 0), 32, 1)
    np.testing.assert_array_equal(np.asarray(Am.data), A * dense_mask)
    # sparse x sparse result mask = boolean matmul of the masks
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=32, block_mask=mask)
    Cm = dbcsr.multiply(Am, Bm, mesh=mesh, algorithm="cannon")
    expected_mask = (mask.astype(int) @ mask.astype(int)) > 0
    np.testing.assert_array_equal(Cm.block_mask, expected_mask)
