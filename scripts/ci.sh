#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): the full suite must COLLECT cleanly and pass.
# Collection failures (missing optional deps, moved jax APIs) broke the
# seed suite once — this script exists so they can't land again.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# collection must produce zero errors even where optional deps are absent
python -m pytest -q --collect-only >/dev/null

python -m pytest -x -q
