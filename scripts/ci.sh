#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md): the full suite must COLLECT cleanly and pass.
# Collection failures (missing optional deps, moved jax APIs) broke the
# seed suite once — this script exists so they can't land again.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# collection must produce zero errors even where optional deps are absent
python -m pytest -q --collect-only >/dev/null

python -m pytest -x -q

# occupancy-aware stacks: the sparse dispatch win is tracked in the
# bench trajectory (artifacts/bench/sparse_smoke.json) and gated —
# --check fails the build if dispatch time stops falling with occupancy
# (also sweeps the executor's size-bin cap: padding must not grow with
# a larger cap)
python benchmarks/bench_sparse.py --smoke --check

# rank-exact execution (ISSUE 9): banded/block-diagonal/power-law
# patterns on a 2x2 mesh (artifacts/bench/sparse_patterns.json) —
# --check fails the build unless rank-exact products are bitwise equal
# to the union plan's, banded executed-triples-per-rank shrink >= 1.5x
# vs union, and the dense uniform-fill collapse adds no dispatch
# regression beyond jitter
python benchmarks/bench_sparse.py --patterns --smoke --check

# norm-based on-the-fly filtering (repro.sparsity): eps sweep +
# McWeeny purification trace (artifacts/bench/filter_smoke.json) —
# --check fails the build if retained triples stop falling with eps,
# if the 5%-retention dispatch is slower than the unfiltered one
# beyond the jitter floor, or if the purification occupancy stops
# decaying after its peak
python benchmarks/bench_filter.py --smoke --check

# multiply planner: recalibrates the cost model on this machine, sweeps
# square/tall/skinny x occupancy fills, and gates planner regret — the
# auto plan must be within 10% (+1ms interpret-mode jitter floor) of
# the best fixed (algorithm, local-path) choice at every sweep point
# (artifacts/bench/planner_smoke.json)
python benchmarks/bench_planner.py --smoke --check

# schedule engine: pipeline_depth 1 vs 2 for cannon/summa/cannon25d —
# the double-buffered driver must never lose to the serial one beyond
# the jitter floor, and the measured per-algorithm overlap constants
# feed the planner calibration (artifacts/bench/overlap_smoke.json)
python benchmarks/bench_overlap.py --smoke --check

# batched multiply service: fused one-dispatch batches vs the looped
# per-request baseline (artifacts/bench/batched_smoke.json) — --check
# fails the build unless the fused path clears 2x looped requests/s on
# >= 16 small same-geometry requests (results cross-checked bitwise)
python benchmarks/bench_batched.py --smoke --check

# ABFT self-verifying multiply (repro.robustness): verified-vs-plain
# overhead on the pinned config plus an injected-corruption sweep
# (artifacts/bench/abft_smoke.json) — --check fails the build unless
# verify="checksum" costs <= 25% wall-clock, every injected corruption
# is detected, localized to the exact block, and repaired to the
# bitwise-clean product, with zero false positives on clean and
# eps-filtered runs
python benchmarks/bench_abft.py --smoke --check

# chaos gate: the full injection matrix ({cannon,summa} x {dense,5%}
# x {bitflip,nan,scale}) on 1x1 and 2x2 meshes via the CLI
# (artifacts/bench/chaos_smoke.json) — nonzero exit unless every cell
# passes
PYTHONPATH=src python -m repro.robustness.chaos --report

# telemetry (repro.obs): tracing-on overhead <= 5% (or inside the
# baseline's own jitter spread), exported Chrome trace validates with
# span durations consistent against the measured dispatch wall time,
# and the pinned algorithm sweep leaves a finite predicted-vs-actual
# scoreboard row per algorithm (artifacts/bench/obs_smoke.json)
python benchmarks/bench_obs.py --smoke --check

# tensor contractions (repro.tensor): the planner's matricization
# choice must be within 10% (+1 ms jitter floor) of the best fixed
# layout over square/tall/skinny contraction geometries, and the
# blocked executor dispatch built from the LOWERED N-d masks must get
# monotonically cheaper — fewer retained triples AND no slower — as
# tensor fill falls 100/50/20/5% (artifacts/bench/tensor_smoke.json)
python benchmarks/bench_tensor.py --smoke --check

# planner drift: compare the sweep's predicted-vs-measured log
# (artifacts/obs/plan_outcomes.jsonl, written by bench_obs) against the
# calibration — advisory here (no --strict): interpret-mode hosts run
# far from the calibrated model, so the scoreboard is printed for the
# trajectory rather than gated
python -m repro.planner.calibrate --check-drift || true
